"""SPSC shared-memory ring: record round-trips across wrap boundaries,
commit-before-visible ordering, overflow-never-blocks, protocol-misuse
errors, and crash tolerance — a producer killed mid-record leaves the
ring cleanly consumable (the torn record is unreachable, not skipped)."""
import os
import struct

import pytest

from repro.core.shmring import (RingPair, ShmRing, ShmRingCorruption,
                                ShmRingError, WRAP_MARKER)


def _drain(ring):
    out = []
    while True:
        got = ring.pop()
        if got is None:
            return out
        seq, view = got
        out.append((seq, bytes(view)))
        ring.release()


def test_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        ShmRing(16)


def test_simple_roundtrip_and_fifo():
    r = ShmRing(1 << 12)
    payloads = [b"", b"x", b"hello" * 10, bytes(range(256))]
    seqs = [r.push(p) for p in payloads]
    assert seqs == [0, 1, 2, 3]
    assert _drain(r) == list(enumerate(payloads))
    assert r.used() == 0


def test_wrap_boundary_roundtrip():
    """Records whose sizes never divide the capacity force the wrap
    marker path over and over; every byte still round-trips in order."""
    r = ShmRing(1 << 12)
    sent = []
    seq = 0
    for i in range(300):
        p = bytes([i % 251]) * (17 + 37 * (i % 29))
        s = r.push(p)
        while s is None:    # full: drain one and retry (never blocks)
            got = r.pop()
            assert got is not None and bytes(got[1]) == sent.pop(0)
            r.release()
            s = r.push(p)
        assert s == seq
        seq += 1
        sent.append(p)
    for p in sent:
        got = r.pop()
        assert got is not None and bytes(got[1]) == p
        r.release()
    assert r.pop() is None


def test_overflow_returns_none_and_counts():
    r = ShmRing(1 << 12)
    assert r.try_reserve(r.capacity) is None
    assert r.overflows == 1
    assert r.push(b"y" * (1 << 11)) is not None
    assert r.try_reserve(1 << 11) is None       # header no longer fits
    assert r.overflows == 2
    # consumer frees the span; the same reservation now succeeds
    r.pop()
    r.release()
    assert r.try_reserve(1 << 11) is not None


def test_reserve_max_commit_partial_and_cancel():
    r = ShmRing(1 << 12)
    mv = r.reserve_max()
    assert len(mv) == r.capacity - 8
    mv[:5] = b"abcde"
    assert r.commit(5) == 0
    assert _drain(r) == [(0, b"abcde")]
    mv = r.reserve_max()
    with pytest.raises(ShmRingError, match="larger than reservation"):
        r.commit(len(mv) + 1)
    r.cancel()
    assert r.push(b"after-cancel") == 1
    assert _drain(r) == [(1, b"after-cancel")]


def test_protocol_misuse_raises():
    r = ShmRing(1 << 12)
    r.try_reserve(8)
    with pytest.raises(ShmRingError, match="already pending"):
        r.try_reserve(8)
    with pytest.raises(ShmRingError, match="already pending"):
        r.reserve_max()
    r.cancel()
    with pytest.raises(ShmRingError, match="no pending"):
        r.commit(0)
    with pytest.raises(ShmRingError, match="no popped"):
        r.release()
    r.push(b"zz")
    r.pop()
    with pytest.raises(ShmRingError, match="not yet released"):
        r.pop()


def test_uncommitted_record_is_unreachable():
    """The consumer must never observe a reserved-but-uncommitted
    record: the tail only moves at commit, so a half-written payload is
    simply not there."""
    r = ShmRing(1 << 12)
    mv = r.try_reserve(64)
    mv[:64] = b"A" * 64            # fully written, never committed
    assert r.pop() is None
    r.commit(64)
    assert bytes(r.pop()[1]) == b"A" * 64
    r.release()


def test_sequence_corruption_detected():
    r = ShmRing(1 << 12)
    r.push(b"fine")
    # smash the committed record's sequence word
    struct.pack_into("<I", r.data, 4, 7)
    with pytest.raises(ShmRingCorruption, match="sequence"):
        r.pop()


def test_wrap_marker_without_record_detected():
    r = ShmRing(1 << 12)
    r.push(b"q" * 16)
    struct.pack_into("<I", r.data, 0, WRAP_MARKER)
    with pytest.raises(ShmRingCorruption, match="wrap marker"):
        r.pop()


def test_cross_process_fork_roundtrip():
    """The mmap region really is shared: a forked child produces, the
    parent consumes the same physical pages."""
    r = ShmRing(1 << 16)
    payloads = [bytes([i]) * (100 + i) for i in range(40)]
    pid = os.fork()
    if pid == 0:                    # child: producer
        code = 0
        try:
            for p in payloads:
                if r.push(p) is None:
                    code = 2
        except BaseException:
            code = 3
        os._exit(code)
    _, status = os.waitpid(pid, 0)
    assert os.waitstatus_to_exitcode(status) == 0
    assert _drain(r) == list(enumerate(payloads))


def test_torn_write_producer_crash_skipped_cleanly():
    """A producer SIGKILL-equivalent death mid-record (reserved, payload
    half-written, never committed) must leave every *committed* record
    readable and the torn one invisible — the consumer sees a clean
    end-of-stream, not garbage."""
    r = ShmRing(1 << 16)
    pid = os.fork()
    if pid == 0:
        r.push(b"committed-1")
        r.push(b"committed-2")
        mv = r.reserve_max()
        mv[:9] = b"torn-torn"       # crash before commit
        os._exit(0)
    os.waitpid(pid, 0)
    assert _drain(r) == [(0, b"committed-1"), (1, b"committed-2")]
    assert r.pop() is None


def test_ring_pair_create():
    pair = RingPair.create(1 << 13)
    assert pair.up.capacity == 1 << 13
    assert pair.down.capacity == 1 << 13
    pair.up.push(b"up")
    pair.down.push(b"down")
    assert bytes(pair.up.pop()[1]) == b"up"
    assert bytes(pair.down.pop()[1]) == b"down"
