"""Fault-tolerant multi-process pod tier: fault-free equivalence with
the in-process tier, bounded-staleness coverage semantics, degraded-mode
suppression/annotation, and the kill → respawn → resync → recover
lifecycle across real OS process boundaries."""
from typing import List

import pytest

from repro.core import simcluster as sc
from repro.core.pod import (MultiProcPodService, PodTierService,
                            POD_FAULT_KINDS)
from repro.core.sharded import shard_of
from repro.core.trace import ColumnarBatch, WireEncoder

LAYOUT = [[0, 1, 2, 3, 4, 5, 6, 7], [7, 8, 9, 10, 11, 12, 13, 14]]
N_PODS = 4   # with this layout/seed the two groups land on pods 3 and 1


class _Driver:
    """Columnar wire-session driver for one service instance."""

    def __init__(self, svc, seed: int = 3):
        self.svc = svc
        self.cl = sc.cascade_fleet(LAYOUT, links=((0, 1),), seed=seed,
                                   columnar=True, samples_per_iter=120)
        self.enc = WireEncoder(self.cl.tables)

    def run(self, iterations: int, process_every: int = 10) -> List:
        out = []
        for _ in range(iterations):
            profiles = self.cl.step()
            self.svc.ingest_encoded(bytes(self.enc.encode(
                ColumnarBatch("job-0", profiles, "node-0",
                              self.cl.tables))))
            self.enc.commit()
            if self.cl.iteration % process_every == 0:
                out.extend(self.svc.process())
        return out

    def add_root_fault(self, rank: int = 2) -> None:
        self.cl.add_fleet_fault(sc.thermal_throttle(
            rank=rank, start=self.cl.iteration, factor=1.5))


def _event_keys(svc):
    out = []
    for e in svc.events:
        d = e.to_dict()
        d.pop("detected_at")
        d.pop("diagnosis_latency_s")
        out.append(d)
    return out


@pytest.fixture(scope="module")
def fault_free_pair():
    """In-process and multi-process pod tiers driven identically
    through a baseline + thermal-throttle cascade, no faults injected
    into the collection plane itself."""
    inproc = PodTierService(n_pods=N_PODS, pods_per_shard=1)
    multi = MultiProcPodService(n_pods=N_PODS)
    for svc in (inproc, multi):
        d = _Driver(svc)
        d.run(30)
        d.add_root_fault()
        d.run(30)
        svc.process()
    yield inproc, multi
    multi.close()


def test_fault_free_event_for_event_equivalence(fault_free_pair):
    inproc, multi = fault_free_pair
    ka, kb = _event_keys(inproc), _event_keys(multi)
    assert ka, "scenario produced no events — vacuous equivalence"
    assert ka == kb


def test_fault_free_snapshot_parity(fault_free_pair):
    inproc, multi = fault_free_pair
    sa, sb = inproc.snapshot(), multi.snapshot()
    assert [g.group_id for g in sa.groups] == \
        [g.group_id for g in sb.groups]
    for ga, gb in zip(sa.groups, sb.groups):
        assert ga.ranks == gb.ranks
        assert ga.last_iteration == gb.last_iteration
        assert (ga.blame is None) == (gb.blame is None)
    assert sa.blame_roots.keys() == sb.blame_roots.keys()


def test_fault_free_stats_and_ft_counters(fault_free_pair):
    _, multi = fault_free_pair
    st = multi.stats()
    assert st["coverage_fraction"] == 1.0
    assert st["pods_live"] == float(N_PODS)
    assert st["pods_dead"] == 0.0
    assert st["pods_warming"] == 0.0
    assert st["pod_respawns"] == 0.0
    assert st["pod_rpc_timeouts"] == 0.0
    assert st["session_resyncs"] == 0.0
    assert st["suppressed_low_coverage"] == 0.0
    # default rings are ample for these frames: the fast path never
    # degraded to the pipe
    assert st["ring_overflows"] == 0.0
    assert st["ring_fallback_uploads"] == 0.0
    assert st["ingested"] == float(multi.ingested) > 0
    # the snapshot carries the same stats, and the query plane serves
    # them under the "stats" kind
    assert multi.snapshot().stats["coverage_fraction"] == 1.0
    assert multi.snapshot().stats["ring_fallback_uploads"] == 0.0
    q = multi.query("stats")
    assert q["stats"]["coverage_fraction"] == 1.0
    assert q["stats"]["ring_overflows"] == 0.0


def test_standing_verdicts_merged_from_workers(fault_free_pair):
    inproc, multi = fault_free_pair
    assert multi.standing_verdicts().keys() == \
        inproc.standing_verdicts().keys()


def test_pod_fault_validation(fault_free_pair):
    _, multi = fault_free_pair
    with pytest.raises(ValueError, match="unknown pod fault"):
        PodTierService(n_pods=2).inject_pod_fault(0, "meteor_strike")
    assert set(POD_FAULT_KINDS) == {"pod_kill", "pod_slow"}


def test_tiny_ring_overflow_falls_back_to_pipe_with_parity(
        fault_free_pair):
    """Rings too small for the session frames: every oversized upload
    must fall back to the pipe copy (counted, never blocking, never
    reordered) and the diagnosis output must stay event-for-event equal
    to the in-process tier — the fast path degrading is an operator
    signal, not a semantic change."""
    inproc, _ = fault_free_pair
    svc = MultiProcPodService(n_pods=N_PODS, ring_bytes=4096)
    with svc:
        d = _Driver(svc)
        d.run(30)
        d.add_root_fault()
        d.run(30)
        svc.process()
        st = svc.stats()
        assert st["ring_fallback_uploads"] > 0
        assert st["ring_overflows"] + st["ring_fallback_uploads"] >= \
            st["ring_fallback_uploads"]
        assert _event_keys(svc) == _event_keys(inproc)


def test_pipe_only_mode_still_works(fault_free_pair):
    """``ring_bytes=None`` keeps the PR 9 pipe-copied plane intact."""
    inproc, _ = fault_free_pair
    svc = MultiProcPodService(n_pods=N_PODS, ring_bytes=None)
    with svc:
        d = _Driver(svc)
        d.run(30)
        d.add_root_fault()
        d.run(30)
        svc.process()
        st = svc.stats()
        assert st["ring_fallback_uploads"] == 0.0   # no rings, no fallback
        assert _event_keys(svc) == _event_keys(inproc)


def test_kill_degrade_suppress_respawn_resync_recover():
    """The full lifecycle over real processes: SIGKILL the root group's
    pod worker mid-fault → the degraded window is visible (coverage,
    warming, suppression — and no cross-group misblame escapes) → the
    supervisor respawns the worker, the wire session resyncs, coverage
    returns to exactly 1.0, and the true root localizes again."""
    svc = MultiProcPodService(n_pods=N_PODS, stale_after=1,
                              respawn_warmup=3)
    with svc:
        d = _Driver(svc)
        d.run(30)
        d.add_root_fault(rank=2)
        d.run(10)
        assert any(e.straggler_rank == 2 for e in svc.events)
        root_group = next(g for g, rs in svc._fl_group_ranks.items()
                          if 2 in rs and 0 in rs)
        root_pod = shard_of(root_group, N_PODS)
        victim_pods = {shard_of(g, N_PODS)
                       for g in svc._fl_group_ranks} - {root_pod}
        assert victim_pods, "layout no longer spans pods; fix LAYOUT"

        svc.inject_pod_fault(root_pod, "pod_kill")
        degraded, warming_seen, suppressed = 0, 0, 0
        for _ in range(3):
            evs = d.run(10)
            st = svc.stats()
            if st["coverage_fraction"] < 1.0:
                degraded += 1
            warming_seen += int(st["pods_warming"] > 0)
            suppressed = int(st["suppressed_low_coverage"])
            # nothing concluded under low coverage may blame the dark
            # pod's ranks (bridge-rank misblame is the failure mode)
            for e in evs:
                if "coverage" in e.evidence:
                    assert e.evidence["coverage"]["degraded"] is True
        assert degraded >= 1, "kill never degraded coverage"
        assert warming_seen >= 1, "respawned pod never reported warming"
        assert suppressed >= 1, "low-coverage suppression never engaged"

        d.run(60)
        st = svc.stats()
        assert st["coverage_fraction"] == 1.0, "coverage never recovered"
        assert st["pod_respawns"] >= 1
        assert st["session_resyncs"] >= 1
        assert st["pods_warming"] == 0.0
        tail = [e for e in svc.events[-12:]
                if e.straggler_rank == 2 and e.group_id == root_group]
        assert tail, "root did not re-localize after recovery"


def test_pod_slow_and_bounded_staleness_inprocess():
    """``pod_slow`` on the in-process tier: the wedged pod's cached
    digest stays usable for ``stale_after`` cycles (no degradation),
    then the pod drops out of the merge; clearing the fault restores
    full coverage immediately (no state was lost, so no warm-up)."""
    svc = PodTierService(n_pods=N_PODS, pods_per_shard=1, stale_after=2)
    d = _Driver(svc)
    d.run(30)
    pod = shard_of(next(iter(svc._known_groups)), N_PODS)
    svc.inject_pod_fault(pod, "pod_slow")
    svc.process()
    st = svc.stats()
    assert st["coverage_fraction"] == 1.0, (
        "digest within the staleness watermark must still count")
    svc.process()
    assert svc.stats()["coverage_fraction"] == 1.0
    svc.process()    # now past stale_after=2: the pod goes dark
    st = svc.stats()
    assert st["coverage_fraction"] < 1.0
    assert st["pods_dead"] == 1.0
    svc.clear_pod_fault(pod)
    svc.process()
    st = svc.stats()
    assert st["coverage_fraction"] == 1.0
    assert st["pods_warming"] == 0.0     # no respawn -> no warm-up


def test_facade_eviction_requires_fresh_digest():
    """A dark pod's groups are never retired on silence, and clearing
    the fault brings them back without loss of facade history."""
    svc = MultiProcPodService(n_pods=N_PODS, stale_after=0)
    with svc:
        d = _Driver(svc)
        d.run(20)
        groups_before = set(svc._fl_group_ranks)
        pod = shard_of(sorted(groups_before)[0], N_PODS)
        svc.inject_pod_fault(pod, "pod_slow")
        d.run(10)
        assert set(svc._fl_group_ranks) == groups_before, (
            "silent pod's groups were evicted from the facade")
        svc.clear_pod_fault(pod)
        d.run(10)
        assert set(svc._fl_group_ranks) == groups_before
        assert svc.stats()["coverage_fraction"] == 1.0
