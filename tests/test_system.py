"""End-to-end system behaviour: real training with the observability stack
attached, checkpoint/restart, the full agent->service->diagnosis loop
on real (not simulated) collective timings, and sharded-vs-unsharded
service equivalence on the paper's five §5.4 case studies."""
import dataclasses
import tempfile

import jax
import pytest

from repro import configs
from repro.core import simcluster as sc
from repro.core.service import CentralService
from repro.core.sharded import ShardedService
from repro.data import DataPipeline, SyntheticCorpus
from repro.models import build_model
from repro.train.loop import LoopConfig, train_loop


@pytest.fixture(scope="module")
def tiny_model():
    cfg = dataclasses.replace(configs.tiny("llama3.2-1b"),
                              param_dtype="float32")
    return build_model(cfg)


def test_train_loop_learns(tiny_model):
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    pipe = DataPipeline(corpus, global_batch=8)
    res = train_loop(tiny_model, pipe,
                     LoopConfig(total_steps=60, warmup_steps=5,
                                peak_lr=1e-3, log_every=1000,
                                observability=False))
    first = sum(res.losses[:5]) / 5
    last = sum(res.losses[-5:]) / 5
    assert last < first - 0.1, (first, last)


def test_train_loop_with_observability_and_resume(tiny_model):
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    svc = CentralService()
    with tempfile.TemporaryDirectory() as d:
        pipe = DataPipeline(corpus, global_batch=8)
        train_loop(tiny_model, pipe,
                   LoopConfig(total_steps=20, warmup_steps=5,
                              checkpoint_every=10, checkpoint_dir=d,
                              log_every=1000, sampling_rate=0.5),
                   service=svc)
        assert svc.ingested >= 1            # agent uploaded profiles
        # resume: picks up at step 20 from the step-20 checkpoint
        pipe2 = DataPipeline(corpus, global_batch=8)
        res2 = train_loop(tiny_model, pipe2,
                          LoopConfig(total_steps=25, warmup_steps=5,
                                     checkpoint_every=10, checkpoint_dir=d,
                                     log_every=1000),
                          service=svc)
        assert len(res2.losses) == 5        # only steps 20..25 ran


def test_real_profiler_collects_from_training(tiny_model):
    """The real SamplingProfiler attached to real JAX training produces
    aggregated python stacks (the §5.1 instrument)."""
    from repro.core.agent import AgentConfig, NodeAgent
    agent = NodeAgent(AgentConfig(sampling_rate=1.0, hz=200.0))
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    pipe = DataPipeline(corpus, global_batch=8)
    agent.start()
    try:
        train_loop(tiny_model, pipe,
                   LoopConfig(total_steps=8, warmup_steps=2, log_every=1000,
                              observability=False))
    finally:
        agent.stop()
    stacks = agent.drain_stacks()
    assert stacks, "sampler collected nothing"
    assert agent.sampler.kept > 0
    assert agent.aggregator.stats.reduction >= 1.0


# ---------------------------------------------------------------------------
# cross-path equivalence over the whole scenario registry (legacy batch,
# streaming object, wire-encoded columnar and sharded paths, event for
# event) lives in tests/test_scenarios.py — one run of
# simcluster.run_scenario_matrix asserts both the expected verdicts and
# path-equality, replacing the hand-enumerated five-case tests that used
# to sit here.  Below: the multi-group concurrent-fault equivalence case
# the matrix does not cover.
# ---------------------------------------------------------------------------


def test_sharded_matches_unsharded_multi_group():
    """Concurrent faults in different groups, groups spread over shards:
    the merged sharded view reports exactly the unsharded diagnoses."""
    def drive(svc):
        fleet = sc.MultiGroupSimCluster(n_groups=6, ranks_per_group=8,
                                        seed=11, samples_per_iter=100)
        fleet.run(svc, 30)
        fleet.add_fault(0, sc.nic_softirq(2, start=30))
        fleet.add_fault(3, sc.thermal_throttle(5, start=30))
        fleet.run(svc, 60)
        return sorted((e.group_id, e.root_cause, e.straggler_rank)
                      for e in svc.events)

    plain = drive(CentralService(window=50))
    sharded = drive(ShardedService(n_shards=4, window=50))
    assert plain and sharded == plain
    causes = {c for _, c, _ in plain}
    assert {"nic_softirq_contention", "gpu_uniform_slowdown"} <= causes
