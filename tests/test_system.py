"""End-to-end system behaviour: real training with the observability stack
attached, checkpoint/restart, the full agent->service->diagnosis loop
on real (not simulated) collective timings, and sharded-vs-unsharded
service equivalence on the paper's five §5.4 case studies."""
import dataclasses
import tempfile

import jax
import pytest

from repro import configs
from repro.core import simcluster as sc
from repro.core.service import CentralService
from repro.core.sharded import ShardedService
from repro.data import DataPipeline, SyntheticCorpus
from repro.models import build_model
from repro.train.loop import LoopConfig, train_loop


@pytest.fixture(scope="module")
def tiny_model():
    cfg = dataclasses.replace(configs.tiny("llama3.2-1b"),
                              param_dtype="float32")
    return build_model(cfg)


def test_train_loop_learns(tiny_model):
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    pipe = DataPipeline(corpus, global_batch=8)
    res = train_loop(tiny_model, pipe,
                     LoopConfig(total_steps=60, warmup_steps=5,
                                peak_lr=1e-3, log_every=1000,
                                observability=False))
    first = sum(res.losses[:5]) / 5
    last = sum(res.losses[-5:]) / 5
    assert last < first - 0.1, (first, last)


def test_train_loop_with_observability_and_resume(tiny_model):
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    svc = CentralService()
    with tempfile.TemporaryDirectory() as d:
        pipe = DataPipeline(corpus, global_batch=8)
        train_loop(tiny_model, pipe,
                   LoopConfig(total_steps=20, warmup_steps=5,
                              checkpoint_every=10, checkpoint_dir=d,
                              log_every=1000, sampling_rate=0.5),
                   service=svc)
        assert svc.ingested >= 1            # agent uploaded profiles
        # resume: picks up at step 20 from the step-20 checkpoint
        pipe2 = DataPipeline(corpus, global_batch=8)
        res2 = train_loop(tiny_model, pipe2,
                          LoopConfig(total_steps=25, warmup_steps=5,
                                     checkpoint_every=10, checkpoint_dir=d,
                                     log_every=1000),
                          service=svc)
        assert len(res2.losses) == 5        # only steps 20..25 ran


def test_real_profiler_collects_from_training(tiny_model):
    """The real SamplingProfiler attached to real JAX training produces
    aggregated python stacks (the §5.1 instrument)."""
    from repro.core.agent import AgentConfig, NodeAgent
    agent = NodeAgent(AgentConfig(sampling_rate=1.0, hz=200.0))
    corpus = SyntheticCorpus(tiny_model.cfg.vocab_size, 64, seed=0)
    pipe = DataPipeline(corpus, global_batch=8)
    agent.start()
    try:
        train_loop(tiny_model, pipe,
                   LoopConfig(total_steps=8, warmup_steps=2, log_every=1000,
                              observability=False))
    finally:
        agent.stop()
    stacks = agent.drain_stacks()
    assert stacks, "sampler collected nothing"
    assert agent.sampler.kept > 0
    assert agent.aggregator.stats.reduction >= 1.0


# ---------------------------------------------------------------------------
# sharded front-end equivalence: hash-partitioning groups across shards must
# not change any diagnosis — same five §5.4 case studies, same verdicts
# ---------------------------------------------------------------------------

CASE_FAULTS = {
    "gpu_thermal_throttle": (lambda: sc.thermal_throttle(0, start=30), False),
    "nic_softirq": (lambda: sc.nic_softirq(4, start=30), False),
    "vfs_dentry_lock": (lambda: sc.vfs_lock_contention([2, 3], start=30), True),
    "logging_overhead": (lambda: sc.logging_overhead(start=30), False),
    "storage_io": (lambda: sc.io_bottleneck(start=30), False),
}


def _drive(service, fault_factory, seed=7, columnar=False, encoded=False):
    """Run the §5.4 scenario into ``service`` over one of the three ingest
    representations: dataclass objects, native columnar profiles, or
    wire-encoded columnar batches (one per fleet iteration, as an agent
    would upload)."""
    from repro.core.trace import ColumnarBatch, encode_batch

    cl = sc.SimCluster(n_ranks=8, seed=seed, columnar=columnar)

    def run(iterations):
        for _ in range(iterations):
            profiles = cl.step()
            if encoded:
                service.ingest_encoded(encode_batch(
                    ColumnarBatch("job-0", profiles, "node-0", cl.tables)))
            else:
                for p in profiles:
                    service.ingest(p)
            if cl.iteration % 10 == 0:
                service.process()
        service.process()

    run(30)
    cl.add_fault(fault_factory())
    run(60)
    return [(e.group_id, e.root_cause, e.category, e.straggler_rank)
            for e in service.events]


@pytest.mark.parametrize("case", sorted(CASE_FAULTS))
def test_sharded_matches_unsharded_on_case_studies(case):
    fault_factory, robust = CASE_FAULTS[case]
    plain = _drive(CentralService(window=50, robust_detector=robust),
                   fault_factory)
    sharded = _drive(ShardedService(n_shards=4, window=50,
                                    robust_detector=robust),
                     fault_factory)
    assert plain, f"case {case} produced no diagnosis"
    assert sharded == plain


@pytest.mark.parametrize("case", sorted(CASE_FAULTS))
def test_case_studies_identical_on_legacy_streaming_columnar_paths(case):
    """The tentpole invariant: the legacy batch path, the streaming object
    path and the wire-encoded columnar path reach the same diagnoses on
    every §5.4 case study."""
    fault_factory, robust = CASE_FAULTS[case]
    legacy = _drive(CentralService(window=50, robust_detector=robust,
                                   streaming=False), fault_factory)
    streaming = _drive(CentralService(window=50, robust_detector=robust),
                       fault_factory)
    columnar = _drive(CentralService(window=50, robust_detector=robust),
                      fault_factory, columnar=True, encoded=True)
    assert streaming, f"case {case} produced no diagnosis"
    assert columnar == streaming
    assert legacy == streaming


def test_sharded_matches_unsharded_multi_group():
    """Concurrent faults in different groups, groups spread over shards:
    the merged sharded view reports exactly the unsharded diagnoses."""
    def drive(svc):
        fleet = sc.MultiGroupSimCluster(n_groups=6, ranks_per_group=8,
                                        seed=11, samples_per_iter=100)
        fleet.run(svc, 30)
        fleet.add_fault(0, sc.nic_softirq(2, start=30))
        fleet.add_fault(3, sc.thermal_throttle(5, start=30))
        fleet.run(svc, 60)
        return sorted((e.group_id, e.root_cause, e.straggler_rank)
                      for e in svc.events)

    plain = drive(CentralService(window=50))
    sharded = drive(ShardedService(n_shards=4, window=50))
    assert plain and sharded == plain
    causes = {c for _, c, _ in plain}
    assert {"nic_softirq_contention", "gpu_uniform_slowdown"} <= causes
