"""Logical->physical sharding rules.  Uses an abstract 16x16 Mesh built
from the single CPU device via AbstractMesh (no 512-device requirement)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.parallel.sharding import ShardingRules, batch_pspec, logical_to_pspec

MESH = AbstractMesh((("data", 16), ("model", 16)))
RULES = ShardingRules()


def _ps(axes, shape, rules=RULES):
    return logical_to_pspec(tuple(axes), tuple(shape), MESH, rules)


def test_embed_table_vocab_tp_embed_fsdp():
    assert _ps(("vocab", "embed"), (151936, 896)) == P("model", "data")


def test_mlp_ffn_tp():
    assert _ps(("embed", "ffn"), (896, 4864)) == P("data", "model")


def test_moe_many_experts_ep():
    # qwen3-moe: 128 experts -> EP on model axis; embed FSDP; ffn replicated
    assert _ps(("experts", "embed", "ffn"), (128, 2048, 768)) == \
        P("model", "data", None)


def test_moe_few_experts_falls_to_ffn_tp():
    # mixtral: 8 experts %% 16 != 0 -> expert dim replicated, ffn gets TP
    assert _ps(("experts", "embed", "ffn"), (8, 6144, 16384)) == \
        P(None, "data", "model")


def test_mqa_kv_head_replicated():
    # gemma: kv=1 cannot shard; head_dim not a model-axis candidate
    assert _ps(("embed", "kv_heads", "head_dim"), (2048, 1, 256)) == \
        P("data", None, None)


def test_q_heads_tp_when_divisible():
    assert _ps(("embed", "q_heads", "head_dim"), (2560, 32, 128)) == \
        P("data", "model", None)


def test_q_heads_replicated_when_indivisible():
    # qwen2-0.5b: 14 heads %% 16 -> replicated; FSDP still on embed
    assert _ps(("embed", "q_heads", "head_dim"), (896, 14, 64)) == \
        P("data", None, None)


def test_kv_cache_heads_sharded_or_seq_sharded():
    # zamba2: kv=32 -> heads on model axis
    assert _ps(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
               (9, 128, 32768, 32, 80)) == \
        P(None, "data", None, "model", None)
    # mixtral decode: kv=8 -> context-parallel seq sharding kicks in
    assert _ps(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
               (56, 128, 4096, 8, 128)) == \
        P(None, "data", "model", None, None)


def test_kv_seq_shard_can_be_disabled():
    rules = ShardingRules(shard_kv_seq=False)
    assert _ps(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
               (56, 128, 4096, 8, 128), rules) == \
        P(None, "data", None, None, None)


def test_no_fsdp_variant():
    rules = ShardingRules(fsdp=False)
    assert _ps(("embed", "ffn"), (896, 4864), rules) == P(None, "model")


def test_batch_replicated_when_indivisible():
    # long_500k: batch=1 cannot shard over data=16 -> replicated
    assert _ps(("batch", "ssm_heads", "head_dim"), (1, 32, 64)) == \
        P(None, "model", None)


def test_multipod_batch_axes():
    mesh3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    rules = ShardingRules(pod_axis="pod")
    got = logical_to_pspec(("batch", None), (256, 4096), mesh3, rules)
    assert got == P(("pod", "data"), None)


def test_batch_pspec_tree():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    ps = batch_pspec(batch, MESH, RULES)
    assert ps["tokens"] == P("data", None)


def test_one_model_axis_per_tensor():
    """Never assign the same mesh axis twice in one PartitionSpec."""
    ps = _ps(("experts", "ffn", "vocab"), (128, 4864, 151936))
    axes = [a for a in ps if a is not None]
    flat = []
    for a in axes:
        flat.extend(a if isinstance(a, tuple) else [a])
    assert len(flat) == len(set(flat))
