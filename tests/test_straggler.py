"""Slow-rank detection + clock alignment + instance separation (§3.1–3.2)."""
import random

import pytest

from repro.core.collective import separate_instances
from repro.core.events import CollectiveEvent
from repro.core.straggler import ClockAligner, StragglerDetector


def _make_instance(i, late_rank=None, lateness=0.0, skews=None, n=8,
                   group="g1", base=0.0):
    skews = skews or {}
    evs = []
    t0 = base + i * 0.1
    entries = {r: t0 + (lateness if r == late_rank else 0.0)
               + random.Random(i * 100 + r).gauss(0, 5e-6) for r in range(n)}
    start = max(entries.values())
    exit_t = start + 9e-3
    for r in range(n):
        evs.append(CollectiveEvent(
            rank=r, group_id=group, op="AllReduce",
            entry=entries[r] + skews.get(r, 0.0),
            exit=exit_t + skews.get(r, 0.0), nbytes=1 << 20))
    return evs


def test_flags_late_rank_04ms():
    """The paper's Case 1 magnitude: 0.4 ms late entry in an 8-rank group."""
    det = StragglerDetector(window=50)
    for i in range(30):
        det.observe_instance(_make_instance(i, late_rank=0, lateness=0.4e-3))
    alerts = det.check()
    assert alerts and alerts[0].rank == 0
    assert 0.3e-3 < alerts[0].lateness < 0.5e-3


def test_no_false_positive_on_healthy_group():
    det = StragglerDetector(window=50)
    for i in range(30):
        det.observe_instance(_make_instance(i))
    assert det.check() == []


def test_clock_skew_does_not_fool_detector():
    """Rank 3 has a +5 ms clock offset but is NOT slow; barrier-exit
    alignment must absorb it."""
    skews = {3: 5e-3}
    det = StragglerDetector(window=50)
    for i in range(30):
        det.observe_instance(_make_instance(i, skews=skews))
    assert det.check() == []
    # and the aligner measured the skew (residuals are per (group, rank))
    assert abs(det.aligner.skew(3, "g1") - 5e-3) < 1e-3


def test_skewed_clock_straggler_still_found():
    skews = {3: 5e-3, 5: -2e-3}
    det = StragglerDetector(window=50)
    for i in range(30):
        det.observe_instance(_make_instance(i, late_rank=5, lateness=0.6e-3,
                                            skews=skews))
    alerts = det.check()
    assert alerts and alerts[0].rank == 5


def test_robust_mode_survives_two_stragglers():
    """Beyond-paper: 2/8 ranks slow — mean/std (paper) loses power,
    median/MAD keeps it (DESIGN.md §7-limitation improvement)."""
    paper = StragglerDetector(window=50, robust=False)
    robust = StragglerDetector(window=50, robust=True)
    for i in range(30):
        inst = _make_instance(i, late_rank=None)
        # make ranks 2 AND 3 late by hand
        inst = [CollectiveEvent(e.rank, e.group_id, e.op,
                                e.entry + (6e-2 if e.rank in (2, 3) else 0),
                                e.exit, e.nbytes) for e in inst]
        paper.observe_instance(inst)
        robust.observe_instance(inst)
    assert {a.rank for a in robust.check()} == {2, 3}
    assert len(paper.check()) == 0   # documented paper limitation (§7)


def test_instance_separation_by_temporal_overlap():
    random.seed(0)
    events = []
    for i in range(20):
        events.extend(_make_instance(i))
    random.shuffle(events)
    instances = separate_instances(events)
    assert len(instances) == 20
    for inst in instances:
        ranks = [e.rank for e in inst]
        assert sorted(ranks) == list(range(8))      # one event per rank
        lo = max(e.entry for e in inst)
        hi = min(e.exit for e in inst)
        assert lo <= hi                              # genuinely overlapping


def test_instance_separation_concurrent_ops():
    """Two overlapping AllReduces on different groups stay separate."""
    a = _make_instance(0, group="g1")
    b = _make_instance(0, group="g2")
    instances = separate_instances(a + b)
    assert len(instances) == 2
    groups = {inst[0].group_id for inst in instances}
    assert groups == {"g1", "g2"}


def test_ring_windows_match_bruteforce_after_wrap():
    """The vectorized per-group ring windows must agree with a
    brute-force recomputation once columns wrap — including instances
    covering only a subset of the group's ranks (per-rank cursors stay
    independent) and the every-``refresh_every`` skew median."""
    import numpy as np

    window, refresh = 6, 8
    det = StragglerDetector(window=window, min_instances=2)
    rng = random.Random(11)
    members = [4, 0, 9, 2]
    seen_late = {r: [] for r in members}     # per-rank lateness history
    seen_resid = {r: [] for r in members}    # per-rank exit residuals
    cached = {}                              # simulated skew cache
    since = {r: 0 for r in members}
    for step in range(40):
        ranks = list(members)
        if step % 5 == 3:                    # partial-membership instance
            ranks = ranks[:3]
        entries = np.array([step * 1.0 + rng.gauss(0, 1e-3)
                            for _ in ranks])
        exits = entries + 5e-3 + np.array([rng.gauss(0, 1e-4)
                                           for _ in ranks])
        det.observe_instance_arrays("g", "AllReduce", ranks,
                                    entries.copy(), exits.copy())
        # brute-force twin: residual windows + the lazy refresh cadence
        resid = exits - exits.mean()
        for r, rv in zip(ranks, resid.tolist()):
            seen_resid[r].append(rv)
            since[r] += 1
            if r not in cached or since[r] >= refresh:
                win = sorted(seen_resid[r][-window:])
                cached[r] = win[len(win) // 2]     # k-th smallest
                since[r] = 0
        aligned = entries - np.array([cached[r] for r in ranks])
        lateness = aligned - aligned.mean()
        for r, lv in zip(ranks, lateness.tolist()):
            seen_late[r].append(lv)

    gb = det.blame_summary("g")
    assert gb is not None
    for r in members:
        # windows advanced independently per rank (subset instances skip
        # the absent ranks), so each mean uses that rank's own last
        # ``window`` observations
        tail = seen_late[r][-window:]
        assert gb.lateness[r] == pytest.approx(sum(tail) / len(tail),
                                               abs=1e-15)
        assert det.aligner.skew(r, "g") == cached[r]
    det.forget_group("g")
    assert det.blame_summary("g") is None
    assert det.aligner.skew(members[0], "g") == 0.0
