"""Property tests for the SYPD digest codec: arbitrary digests
(unicode names, empty collections, adversarial floats) round-trip
losslessly, and the decoder rejects — never mis-parses — bad versions,
bad magic, and truncation."""
import struct

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pod import PodDigest  # noqa: E402
from repro.core.straggler import GroupBlame, StragglerAlert  # noqa: E402
from repro.core.trace import WireFormatError  # noqa: E402
from repro.core.transport import (DIGEST_MAGIC, DIGEST_VERSION,  # noqa: E402
                                  DigestFormatError, decode_digest,
                                  encode_digest)

# group names cross the wire as utf-8 length-prefixed strings: give the
# codec real unicode, not just ascii slugs
_names = st.text(min_size=1, max_size=24).filter(
    lambda s: "\x00" not in s)
_ranks = st.integers(min_value=0, max_value=2**40)
# xor-delta float columns are bit-exact for any finite double
_floats = st.floats(allow_nan=False, allow_infinity=False, width=64)


@st.composite
def alerts(draw):
    return StragglerAlert(
        group_id=draw(_names), rank=draw(_ranks),
        lateness=draw(_floats), mean=draw(_floats), std=draw(_floats),
        zscore=draw(_floats),
        window=draw(st.integers(min_value=0, max_value=2**31)))


@st.composite
def blames(draw):
    return GroupBlame(
        group_id=draw(_names),
        ranks=tuple(draw(st.lists(_ranks, max_size=6))),
        culprit_rank=draw(_ranks), culprit_lateness=draw(_floats),
        lateness=draw(st.dictionaries(_ranks, _floats, max_size=5)),
        wait=draw(st.dictionaries(_ranks, _floats, max_size=5)),
        peer_wait=draw(_floats), last_start=draw(_floats),
        instances=draw(st.integers(min_value=0, max_value=2**40)))


@st.composite
def digests(draw):
    n = draw(st.integers(min_value=0, max_value=12))
    sids = np.sort(np.asarray(
        draw(st.lists(st.integers(min_value=0, max_value=2**50),
                      max_size=n, min_size=n, unique=True)),
        dtype=np.int64))
    weights = np.asarray(
        draw(st.lists(_floats, min_size=n, max_size=n)))
    return PodDigest(
        pod=draw(st.integers(min_value=-1, max_value=2**15)),
        alerts=draw(st.lists(alerts(), max_size=4)),
        summaries={b.group_id: b
                   for b in draw(st.lists(blames(), max_size=3))},
        groups=draw(st.integers(min_value=0, max_value=2**20)),
        ranks=draw(st.integers(min_value=0, max_value=2**20)),
        flame_sids=sids, flame_weights=weights,
        group_ranks=draw(st.dictionaries(
            _names, st.lists(_ranks, max_size=5).map(tuple),
            max_size=4)),
        seq=draw(st.integers(min_value=0, max_value=2**31)))


@settings(max_examples=60, deadline=None)
@given(digests())
def test_digest_round_trip(d):
    rt = decode_digest(encode_digest(d))
    assert (rt.pod, rt.seq, rt.groups, rt.ranks) == \
        (d.pod, d.seq, d.groups, d.ranks)
    assert rt.alerts == d.alerts
    assert rt.summaries == d.summaries
    assert rt.group_ranks == d.group_ranks
    np.testing.assert_array_equal(rt.flame_sids, d.flame_sids)
    np.testing.assert_array_equal(rt.flame_weights, d.flame_weights)


@settings(max_examples=40, deadline=None)
@given(digests(), st.integers(min_value=0, max_value=2**16 - 1))
def test_version_negotiation_rejects_foreign_versions(d, version):
    hypothesis.assume(version > DIGEST_VERSION or version < 1)
    frame = bytearray(encode_digest(d))
    frame[4:6] = struct.pack("<H", version)
    with pytest.raises(DigestFormatError, match="version"):
        decode_digest(bytes(frame))


@settings(max_examples=40, deadline=None)
@given(digests(), st.data())
def test_truncation_never_misparses(d, data):
    frame = encode_digest(d)
    cut = data.draw(st.integers(min_value=0, max_value=len(frame) - 1))
    with pytest.raises(WireFormatError):
        decode_digest(frame[:cut])


@settings(max_examples=40, deadline=None)
@given(st.binary(max_size=64))
def test_garbage_rejected(blob):
    hypothesis.assume(not blob.startswith(DIGEST_MAGIC))
    with pytest.raises(WireFormatError):
        decode_digest(blob)
