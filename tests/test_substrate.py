"""Data pipeline, checkpointing, optimizer, compression, fault tolerance."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.data import DataPipeline, SyntheticCorpus
from repro.ft import HeartbeatMonitor, MitigationPlanner
from repro.ft.mitigation import plan_remesh
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, make_schedule
from repro.optim.compress import (compress_grads_int8, compressed_bytes,
                                  dequantize_int8, init_error_state,
                                  quantize_int8)


# -- data ----------------------------------------------------------------------

def test_corpus_deterministic():
    c1 = SyntheticCorpus(256, 32, seed=5)
    c2 = SyntheticCorpus(256, 32, seed=5)
    np.testing.assert_array_equal(c1.sequence(7), c2.sequence(7))
    assert not np.array_equal(c1.sequence(7), c1.sequence(8))


def test_pipeline_sharding_disjoint():
    c = SyntheticCorpus(256, 16, seed=0)
    p0 = DataPipeline(c, global_batch=8, shard_index=0, num_shards=2)
    p1 = DataPipeline(c, global_batch=8, shard_index=1, num_shards=2)
    b0, b1 = p0.build_batch(0), p1.build_batch(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_cursor_resume():
    c = SyntheticCorpus(256, 16, seed=0)
    p = DataPipeline(c, global_batch=4)
    batches = [next(p) for _ in range(3)]
    p2 = DataPipeline(c, global_batch=4, start_cursor=2)
    np.testing.assert_array_equal(next(p2)["tokens"], batches[2]["tokens"])


def test_pipeline_prefetch_thread():
    c = SyntheticCorpus(256, 16, seed=0)
    p = DataPipeline(c, global_batch=4, prefetch=2)
    ref = [p.build_batch(i)["tokens"] for i in range(3)]
    p.start()
    try:
        got = [next(p)["tokens"] for _ in range(3)]
    finally:
        p.stop()
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g)


# -- checkpoint -----------------------------------------------------------------

def _state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, _state(), cursor=123)
        assert latest_step(d) == 7
        restored, manifest = load_checkpoint(d, 7, _state())
        assert manifest["cursor"] == 123
        np.testing.assert_array_equal(restored["params"]["w"],
                                      _state()["params"]["w"])


def test_async_checkpointer_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (10, 20, 30):
            ck.save(s, _state(), cursor=s)
        ck.wait()
        steps = sorted(int(p.name.split("_")[1]) for p in Path(d).iterdir())
        assert steps == [20, 30]


def test_checkpoint_atomicity_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state())
        assert not [p for p in Path(d).iterdir() if p.name.startswith(".tmp")]


# -- optimizer ----------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"x": jnp.asarray(5.0)}
    opt = adamw_init(params)
    for step in range(300):
        g = {"x": 2 * params["x"]}
        params, opt = adamw_update(g, opt, params, lr=0.05,
                                   step=jnp.asarray(step))
    assert abs(float(params["x"])) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10}
    clipped, norm = clip_by_global_norm(g, max_norm=1.0)
    assert float(norm) > 30
    _, n2 = clip_by_global_norm(clipped, max_norm=1e9)
    assert float(n2) <= 1.0 + 1e-5


def test_wsd_schedule_shape():
    sched = make_schedule("wsd", peak_lr=1.0, warmup_steps=10,
                          stable_steps=80, decay_steps=10)
    assert float(sched(jnp.asarray(4))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(50))) == pytest.approx(1.0)   # stable
    assert float(sched(jnp.asarray(99))) < 0.1                   # decayed


def test_cosine_schedule_monotone_after_peak():
    sched = make_schedule("cosine", peak_lr=1.0, warmup_steps=10,
                          total_steps=100)
    vals = [float(sched(jnp.asarray(s))) for s in range(10, 100, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


# -- gradient compression ----------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3)
    q, s = quantize_int8(x)
    dec = dequantize_int8(q, s, x.shape)
    err = jnp.max(jnp.abs(dec - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of decoded grads over steps ~ sum of true grads (error feedback
    makes compression unbiased over time)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(512,)) * 1e-3)
    grads = {"w": g_true}
    err = init_error_state(grads)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        dec, err = compress_grads_int8(grads, err)
        total = total + dec["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true) * 50,
                               rtol=0.05, atol=1e-4)


def test_compression_ratio_accounting():
    g = {"w": jnp.zeros((4096,), jnp.bfloat16)}
    raw, comp = compressed_bytes(g)
    assert raw == 8192
    assert comp < raw * 0.6   # ~4x smaller than bf16 wire size? int8+scales
    # int8 payload 4096 + 16 blocks * 4B scales = 4160 -> ~1.97x vs bf16
    assert comp == 4096 + (4096 // 256) * 4


# -- fault tolerance -----------------------------------------------------------------

def test_heartbeat_failure_detection():
    t = {"now": 0.0}
    hb = HeartbeatMonitor(interval_s=10, miss_threshold=3,
                          clock=lambda: t["now"])
    for n in range(4):
        hb.register(n)
    t["now"] = 25.0
    for n in (0, 1, 2):
        hb.beat(n)
    t["now"] = 35.0
    failures = hb.check()
    assert [f.node for f in failures] == [3]
    assert hb.alive() == [0, 1, 2]


def test_elastic_plan_keeps_batch_divisible():
    plan = plan_remesh(data_axis=16, model_axis=16, lost_nodes=2,
                       chips_per_node=8, global_batch=256)
    assert plan.new_data_axis < 16
    assert 256 % plan.new_data_axis == 0
    assert plan.feasible


def test_planner_reacts_to_failures():
    pl = MitigationPlanner(data_axis=16, model_axis=16)
    from repro.ft.heartbeat import NodeFailure
    acts = pl.on_failures([NodeFailure(node=3, last_beat=0, detected_at=31)])
    assert acts and acts[0].kind == "restart_elastic"
    assert acts[0].plan.new_data_axis < 16
