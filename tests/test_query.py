"""Queryable diagnosis plane: the DiagnosisService protocol, the one
result envelope (to_dict/from_dict round-trips + the detected_at
ordering contract), SLO wildcard expansion, time-travel queries over
snapshot-isolated read state, the eviction regression (a held snapshot
stays readable, retained history and SLO registrations go), and the
fleet audit() walk — identical from CentralService and ShardedService
on a cascade fleet."""
import pytest

from repro.core import simcluster as sc
from repro.core.attribution import BlameTimeline
from repro.core.diffdiag import Verdict
from repro.core.query import (SLO, AuditFinding, DiagnosisService,
                              FleetSnapshot, SLOBreach, expand_slo_targets)
from repro.core.service import CentralService, DiagnosticEvent
from repro.core.sharded import ShardedService

LAYOUT = [[0, 1, 2, 3, 4, 5, 6, 7], [7, 8, 9, 10, 11, 12, 13, 14]]


def _drive(svc, *, seed=3, margin=0.05, samples=120):
    """Healthy cascade fleet, register per-group iteration-time SLOs,
    then inject a root fault in group 0 that cascades into group 1."""
    cl = sc.cascade_fleet(LAYOUT, links=((0, 1),), seed=seed,
                          samples_per_iter=samples)
    for slo in sc.fleet_slos(cl, margin=margin):
        svc.register_slo(slo)
    cl.run(svc, 30)
    cl.add_fleet_fault(sc.thermal_throttle(rank=2, start=30, factor=1.5))
    cl.run(svc, 30)
    return cl


@pytest.fixture(scope="module")
def driven():
    central = CentralService()
    cl = _drive(central)
    sharded = ShardedService(n_shards=3)
    _drive(sharded)
    return cl, central, sharded


# ---------------------------------------------------------------------------
# unified service protocol
# ---------------------------------------------------------------------------


def test_both_services_implement_protocol():
    assert isinstance(CentralService(), DiagnosisService)
    assert isinstance(ShardedService(n_shards=2), DiagnosisService)


def test_epoch_starts_at_zero_and_advances_per_cycle():
    for svc in (CentralService(), ShardedService(n_shards=2)):
        assert svc.snapshot().epoch == 0
        assert svc.snapshot().groups == ()
        svc.process()
        svc.process()
        assert svc.snapshot().epoch == 2
        assert svc.stats()["epoch"] == 2


def test_query_dispatcher_covers_every_kind(driven):
    _cl, central, _sharded = driven
    for kind in ("groups", "slos", "breaches", "audit"):
        resp = central.query(kind)
        assert resp["epoch"] == central.snapshot().epoch
    g = central.snapshot().group_ids()[0]
    assert central.query("metrics", group_id=g)["epoch"] >= 1
    assert central.query("blame_timeline", group_id=g, rank=0)["epoch"] >= 1
    assert central.query("events")["epoch"] >= 1
    with pytest.raises(ValueError):
        central.query("nope")


# ---------------------------------------------------------------------------
# one result envelope
# ---------------------------------------------------------------------------


def test_event_envelope_round_trips(driven):
    _cl, central, _sharded = driven
    assert central.events, "fixture fleet must have diagnosed something"
    for ev in central.events:
        d = ev.to_dict()
        back = DiagnosticEvent.from_dict(d)
        assert back == ev
        if ev.verdict is not None:
            assert Verdict.from_dict(d["verdict"]) == ev.verdict


def test_detected_at_ordering_contract(driven):
    """Stamps are strictly increasing in emission order, so serialized
    streams sort back into exactly the emission order."""
    _cl, central, sharded = driven
    for svc in (central, sharded):
        stamps = [e.detected_at for e in svc.events]
        assert stamps == sorted(stamps)
        assert len(set(stamps)) == len(stamps)


def test_breach_and_finding_envelopes_round_trip(driven):
    _cl, central, _sharded = driven
    breaches = central.check_slos()
    findings = central.audit()
    assert breaches and findings
    for b in breaches:
        assert SLOBreach.from_dict(b.to_dict()) == b
    for f in findings:
        assert AuditFinding.from_dict(f.to_dict()) == f
    slo = next(iter(central._slos.values()))
    assert SLO.from_dict(slo.to_dict()) == slo


def test_satellite_dict_forms(driven):
    cl, central, _sharded = driven
    tl = BlameTimeline.from_dict(
        {"iter_time": 1.0, "compute": 0.6, "host": 0.1, "blocked_wait": 0.1,
         "transfer": 0.1, "residual": 0.1}, group_id="g", rank=3,
        iteration=7)
    assert (tl.rank, tl.iteration, tl.compute) == (3, 7, 0.6)
    g = cl.group_ids()[0]
    blame = central.last_summaries.get(g)
    if blame is not None:
        d = blame.as_dict()
        assert d["group_id"] == g and isinstance(d["lateness"], dict)


# ---------------------------------------------------------------------------
# SLOs: wildcard expansion + evaluation semantics
# ---------------------------------------------------------------------------


def test_wildcard_expansion_against_snapshot(driven):
    cl, central, _sharded = driven
    snap = central.snapshot()
    g0, g1 = cl.group_ids()
    every = expand_slo_targets(SLO("a", "iter_time", 1.0), snap)
    assert set(every) == {(g, r) for g, ranks in zip((g0, g1), LAYOUT)
                          for r in ranks}
    one_rank = expand_slo_targets(
        SLO("b", "iter_time", 1.0, group_id=g0, rank=2), snap)
    assert one_rank == [(g0, 2)]
    # rank not in the group -> no targets, not a phantom target
    assert expand_slo_targets(
        SLO("c", "iter_time", 1.0, group_id=g0, rank=99), snap) == []
    # group-scoped metric expands to (group, None)
    lat = expand_slo_targets(SLO("d", "diagnosis_latency", 1.0), snap)
    assert set(lat) == {(g0, None), (g1, None)}
    # prefix patterns match fnmatch-style
    pref = expand_slo_targets(
        SLO("e", "iter_time", 1.0, group_id=g0[:4] + "*", rank=0), snap)
    assert pref == [(g0, 0)]


def test_unknown_metric_and_window_rejected():
    with pytest.raises(ValueError):
        SLO("x", "made_up_metric", 1.0)
    with pytest.raises(ValueError):
        SLO("x", "iter_time", 1.0, window=0)
    svc = CentralService()
    with pytest.raises(ValueError):
        svc.query_metrics(group_id="g", metric="made_up_metric")


def test_healthy_fleet_is_breach_free():
    svc = CentralService()
    cl = sc.cascade_fleet(LAYOUT, links=((0, 1),), seed=5,
                          samples_per_iter=120)
    for slo in sc.fleet_slos(cl, margin=0.5):
        svc.register_slo(slo)
    cl.run(svc, 20)
    assert svc.check_slos() == []
    assert svc.audit() == []


def test_exposed_compute_and_latency_slos(driven):
    _cl, central, _sharded = driven
    central.register_slo(SLO("compute-floor", "exposed_compute_fraction",
                             0.99, group_id="*"))
    central.register_slo(SLO("diag-lat", "diagnosis_latency", 1e-12))
    try:
        metrics = {b.metric for b in central.check_slos()}
        assert "exposed_compute_fraction" in metrics
        assert "diagnosis_latency" in metrics
    finally:
        central.remove_slo("compute-floor")
        central.remove_slo("diag-lat")


def test_exposed_compute_fraction():
    """The trace satellite: kernel time outside collectives over the
    iteration — the quantity exposed-compute SLOs audit."""
    from repro.core.events import (CollectiveEvent, IterationProfile,
                                   OSSignals)
    from repro.core.events import KernelEvent
    from repro.core.trace import TraceTables, profile_to_columnar
    p = IterationProfile(
        rank=0, iteration=0, group_id="g", iter_time=0.5,
        cpu_samples=[],
        kernel_events=[KernelEvent(0, "a", 0.00, 0.10),
                       KernelEvent(0, "b", 0.30, 0.20)],
        collectives=[CollectiveEvent(0, "g", "AllReduce", 0.40, 0.50,
                                     1024, 0.1)],
        os_signals=OSSignals(rank=0, timestamp=0.0))
    cp = profile_to_columnar(p, TraceTables())
    # kernel b overlaps the collective by 0.1 -> exposed = 0.1 + 0.1
    assert cp.exposed_compute_fraction() == pytest.approx(0.2 / 0.5)


# ---------------------------------------------------------------------------
# time-travel queries
# ---------------------------------------------------------------------------


def test_query_metrics_iteration_range(driven):
    cl, central, _sharded = driven
    g = cl.group_ids()[0]
    resp = central.query_metrics(group_id=g, rank=2, metric="iter_time",
                                 start_iteration=40, end_iteration=45)
    pts = resp["series"][2]
    assert [p["iteration"] for p in pts] == list(range(40, 46))
    # faulted window is visibly slower than the healthy baseline
    healthy = central.query_metrics(group_id=g, rank=2, metric="iter_time",
                                    start_iteration=10,
                                    end_iteration=20)["series"][2]
    assert (sum(p["value"] for p in pts) / len(pts)
            > 1.2 * sum(p["value"] for p in healthy) / len(healthy))


def test_query_blame_timeline_range_and_columns(driven):
    cl, central, _sharded = driven
    g = cl.group_ids()[0]
    resp = central.query_blame_timeline(group_id=g, rank=2,
                                        start_iteration=30)
    assert resp["timelines"], "cycles past iteration 30 must be recorded"
    for row in resp["timelines"]:
        assert row["iteration"] >= 30
        parts = (row["compute"] + row["host"] + row["blocked_wait"]
                 + row["transfer"] + row["residual"])
        assert parts == pytest.approx(row["iter_time"], rel=1e-6)


def test_search_events_filters_and_limit(driven):
    cl, central, _sharded = driven
    g = cl.group_ids()[0]
    resp = central.search_events(group_id=g, limit=3)
    assert len(resp["events"]) <= 3
    assert all(e["group_id"] == g for e in resp["events"])
    stamps = [e["detected_at"] for e in resp["events"]]
    assert stamps == sorted(stamps)
    cause = central.events[-1].root_cause
    by_cause = central.search_events(root_cause=cause)
    assert all(e["root_cause"] == cause for e in by_cause["events"])


def test_list_groups_summary(driven):
    cl, central, _sharded = driven
    resp = central.list_groups()
    assert sorted(g["group_id"] for g in resp["groups"]) \
        == sorted(cl.group_ids())
    for g in resp["groups"]:
        assert g["epoch"] == resp["epoch"]
        assert g["n_ranks"] == 8 and g["mean_iter_time"] > 0
        # step() stamps profiles with the pre-increment iteration index
        assert g["last_iteration"] == cl.iteration - 1
        # waterline names are resolved strings, never interned ids
        assert all(isinstance(name, str) and isinstance(frac, float)
                   for name, frac in g["waterline_top"])


# ---------------------------------------------------------------------------
# snapshot isolation + the eviction regression
# ---------------------------------------------------------------------------


def test_snapshot_immutable_under_further_ingest():
    svc = CentralService()
    cl = sc.SimCluster(n_ranks=4, seed=1, samples_per_iter=80)
    cl.run(svc, 12, process_every=4)
    held = svc.snapshot()
    held_rows = held.history[(cl.group_id, 0)].iter_times()
    held_events = len(held.events)
    cl.run(svc, 12, process_every=4)
    assert svc.snapshot().epoch > held.epoch
    assert held.history[(cl.group_id, 0)].iter_times() == held_rows
    assert len(held.events) == held_events


def test_copy_on_trim_preserves_held_views():
    svc = CentralService(retain=8)      # trim after 16 appends
    cl = sc.SimCluster(n_ranks=2, seed=2, samples_per_iter=40)
    cl.run(svc, 10, process_every=5)
    held = svc.snapshot()
    rows = held.history[(cl.group_id, 0)].iter_times()
    cl.run(svc, 30, process_every=5)    # forces several trims
    assert held.history[(cl.group_id, 0)].iter_times() == rows
    fresh = svc.snapshot().history[(cl.group_id, 0)]
    assert fresh.n_it <= 16


def test_snapshot_survives_eviction_and_state_is_dropped():
    """The satellite bugfix: eviction drops retained history, blame
    roots and exact-match SLO registrations — while a snapshot held
    across the eviction stays fully readable."""
    svc = CentralService()
    cl = sc.SimCluster(n_ranks=4, seed=4, samples_per_iter=80)
    cl.run(svc, 12, process_every=4)
    g = cl.group_id
    svc.register_slo(SLO("exact", "iter_time", 1.0, group_id=g))
    svc.register_slo(SLO("wild", "iter_time", 1.0, group_id="*"))
    held = svc.snapshot()
    held_rows = held.history[(g, 0)].iter_times()
    held_groups = held.group_ids()

    svc.evict_group(g)
    svc.process()

    # held snapshot: same answers as before the eviction
    assert held.group_ids() == held_groups
    assert held.history[(g, 0)].iter_times() == held_rows
    for name, _frac in held.group(g).waterline_top:
        assert isinstance(name, str)      # resolved names, never ids
    # live state: history, blame roots and the exact SLO are gone
    assert all(key[0] != g for key in svc._history)
    assert g not in svc._blame_roots
    assert "exact" not in svc._slos and "wild" in svc._slos
    fresh = svc.snapshot()
    assert fresh.group(g) is None
    assert svc.query_metrics(group_id=g, rank=0)["series"] == {}


def test_facade_eviction_drops_facade_slos():
    svc = ShardedService(n_shards=2)
    cl = sc.SimCluster(n_ranks=4, seed=4, samples_per_iter=80)
    cl.run(svc, 8, process_every=4)
    g = cl.group_id
    svc.register_slo(SLO("exact", "iter_time", 1.0, group_id=g))
    svc.register_slo(SLO("wild", "iter_time", 1.0, group_id="*"))
    held = svc.snapshot()
    svc.evict_group(g)
    svc.process()
    assert "exact" not in svc._slos and "wild" in svc._slos
    assert svc.snapshot().group(g) is None
    assert held.group(g) is not None          # held view unaffected


def test_ttl_eviction_drops_query_state():
    import time as _time
    svc = CentralService(group_ttl_s=100.0)
    cl = sc.SimCluster(n_ranks=4, seed=6, samples_per_iter=80)
    cl.run(svc, 8, process_every=4)
    g = cl.group_id
    svc.register_slo(SLO("exact", "iter_time", 1.0, group_id=g))
    svc._last_ingest[g] = _time.monotonic() - 101.0
    svc.process()
    assert all(key[0] != g for key in svc._history)
    assert "exact" not in svc._slos
    assert svc.snapshot().group(g) is None


# ---------------------------------------------------------------------------
# the fleet audit walk: central == sharded on a cascade
# ---------------------------------------------------------------------------

def _finding_key(f):
    """Causal identity of a finding — everything except wall-clock
    stamps, which legitimately differ between service instances."""
    return (f.breach.slo, f.breach.metric, f.breach.group_id,
            f.breach.rank, f.breach.value, f.breach.threshold,
            f.breach.window, f.breach.epoch, f.root_group, f.root_rank,
            f.root_node, f.root_cause, f.category, f.epoch,
            tuple(f.evidence["chain"]))


def test_audit_walks_every_breach_to_the_root(driven):
    cl, central, _sharded = driven
    root_g, victim_g = cl.group_ids()
    findings = central.audit()
    # every breached (group, rank) shows up exactly once
    assert len(findings) == len(central.check_slos()) == 16
    for f in findings:
        assert f.root_group == root_g
        assert f.root_rank == 2
        assert f.root_node == 2 // central.chips_per_node
        assert f.root_cause == "gpu_uniform_slowdown"
        assert f.epoch == f.breach.epoch == central.snapshot().epoch
    victims = [f for f in findings if f.breach.group_id == victim_g]
    assert len(victims) == 8
    for f in victims:
        assert f.evidence["chain"] == [victim_g, root_g]
        assert f.evidence["via_rank"] == 7          # the bridge rank
        assert f.evidence["root_event"]["root_cause"] \
            == "gpu_uniform_slowdown"
    roots = [f for f in findings if f.breach.group_id == root_g]
    assert any("root_blame_timeline" in f.evidence for f in roots)


def test_audit_identical_central_vs_sharded(driven):
    _cl, central, sharded = driven
    fc = sorted(map(_finding_key, central.audit()))
    fs = sorted(map(_finding_key, sharded.audit()))
    assert fc == fs and len(fc) == 16


def test_audit_without_blame_root_falls_back_to_local_event():
    """A breach in a group with no cascade pointer still resolves to a
    root via the group's own latest diagnosis."""
    svc = CentralService()
    cl = sc.SimCluster(n_ranks=8, seed=9, samples_per_iter=120)
    for slo in sc.fleet_slos(cl, margin=0.05):
        svc.register_slo(slo)
    cl.run(svc, 20)
    cl.add_fault(sc.thermal_throttle(rank=3, start=20, factor=1.5))
    cl.run(svc, 20)
    findings = svc.audit()
    assert findings
    for f in findings:
        assert f.root_group == cl.group_id
        assert f.root_rank == 3
        assert f.evidence["chain"] == [cl.group_id]
