"""Columnar trace pipeline: interning tables, lossless adapters, the
versioned wire codec, table re-mapping, and the agent's encoded-upload
path.  Deterministic tests run everywhere; hypothesis property tests ride
along when dev extras are installed."""
import numpy as np
import pytest

from repro.core import simcluster as sc
from repro.core.agent import AgentConfig, NodeAgent
from repro.core.events import (CollectiveEvent, IterationProfile, KernelEvent,
                               OSSignals, ProfileBatch, StackSample)
from repro.core.flamegraph import FlameGraph
from repro.core.service import CentralService
from repro.core.sharded import ShardedService
from repro.core.trace import (ColumnFlameGraph, ColumnarBatch,
                              ColumnarProfile, TableRemap, TraceTables,
                              WIRE_MIN_VERSION, WIRE_VERSION, WireEncoder,
                              WireFormatError, batch_fraction_rows,
                              decode_batch, encode_batch, profile_to_columnar,
                              remap_profile, to_columnar, to_dataclasses)


def _profile(rank=0, iteration=0, group="g0", with_os=True,
             frames=(("main", "forward", "softmax"),
                     ("main", "backward", "matmul"))):
    samples = [StackSample(rank=rank, timestamp=1.5 + i, frames=f,
                           weight=3 + i, kind="cpu")
               for i, f in enumerate(frames)]
    kernels = [KernelEvent(rank=rank, name="gemm", start=0.1, duration=0.02),
               KernelEvent(rank=rank, name="softmax", start=0.12,
                           duration=0.005, stream=3)]
    colls = [CollectiveEvent(rank=rank, group_id=group, op="AllReduce",
                             entry=1.0, exit=1.1, nbytes=1 << 20,
                             device_duration=0.05, instance=2, seq=7)]
    sig = OSSignals(rank=rank, timestamp=2.0,
                    interrupts={"LOC": 1000, "NET_RX": 50},
                    softirq_residency={"NET_RX": 0.125},
                    sched_latency_p99=80e-6, numa_migrations=3,
                    cpu_steal=0.01) if with_os else None
    return IterationProfile(rank=rank, iteration=iteration, group_id=group,
                            iter_time=0.25, cpu_samples=samples,
                            kernel_events=kernels, collectives=colls,
                            os_signals=sig)


# -- interning ----------------------------------------------------------------

def test_string_and_stack_interning_dedups():
    t = TraceTables()
    a = t.intern_stack(("main", "f", "g"))
    b = t.intern_stack(("main", "f", "g"))
    c = t.intern_stack(("main", "f"))
    assert a == b != c
    assert t.stack_tuple(a) == ("main", "f", "g")
    assert len(t.strings) == 3                 # frames dedup'd
    # cached unique-fn view covers repeated frames once
    d = t.intern_stack(("main", "main", "f"))
    fns = t.stack_fns(d)
    assert sorted(fns) == fns and len(fns) == 2


# -- adapters -----------------------------------------------------------------

def test_adapter_round_trip_is_lossless():
    batch = ProfileBatch("job-1", [_profile(0), _profile(1, 4, "g1"),
                                   _profile(2, with_os=False)], "node-3")
    assert to_dataclasses(to_columnar(batch)) == batch


def test_adapter_preserves_kinds_and_unicode():
    p = IterationProfile(
        rank=0, iteration=0, group_id="grüppe-θ", iter_time=0.1,
        cpu_samples=[StackSample(rank=0, timestamp=0.0,
                                 frames=("päth", "λeaf"), weight=2,
                                 kind="pythön")])
    cp = profile_to_columnar(p)
    assert cp.to_dataclasses() == p


def test_columnar_flamegraph_matches_from_samples():
    p = _profile()
    cp = profile_to_columnar(p)
    assert cp.flamegraph().counts == FlameGraph.from_samples(
        p.cpu_samples).counts


def test_function_fraction_sparse_matches_flamegraph():
    p = _profile(frames=(("main", "a", "b"), ("main", "a"),
                         ("main", "c", "a")))
    cp = profile_to_columnar(p)
    ids, fracs = cp.function_fraction_sparse()
    got = {cp.tables.strings.get(int(i)): float(f)
           for i, f in zip(ids, fracs)}
    ref = FlameGraph.from_samples(p.cpu_samples).function_fractions()
    assert set(got) == set(ref)
    for fn in ref:
        assert got[fn] == pytest.approx(ref[fn])
    assert ids.tolist() == sorted(ids.tolist())


# -- wire codec ---------------------------------------------------------------

def test_wire_round_trip_multi_group_batch():
    batch = ProfileBatch("job-7", [_profile(r, it, g)
                                   for g in ("g0", "g1", "g2")
                                   for it in range(2)
                                   for r in range(3)], "node-9")
    out = decode_batch(encode_batch(batch))
    assert out.job_id == "job-7" and out.node_id == "node-9"
    assert out.to_dataclasses() == batch


def test_wire_round_trip_empty_batch_and_empty_profiles():
    empty = ProfileBatch("j", [], "n")
    assert decode_batch(encode_batch(empty)).to_dataclasses() == empty
    bare = ProfileBatch("j", [IterationProfile(
        rank=0, iteration=0, group_id="g", iter_time=0.0)])
    assert decode_batch(encode_batch(bare)).to_dataclasses() == bare


def test_wire_round_trip_unicode_everywhere():
    p = IterationProfile(
        rank=1, iteration=2, group_id="グループ", iter_time=0.5,
        cpu_samples=[StackSample(rank=1, timestamp=0.0,
                                 frames=("рамка", "🔥"), weight=1,
                                 kind="mixed")],
        kernel_events=[KernelEvent(rank=1, name="gemm_ß", start=0.0,
                                   duration=1e-3)],
        collectives=[CollectiveEvent(rank=1, group_id="グループ",
                                     op="AllGather", entry=0.0, exit=0.1)],
        os_signals=OSSignals(rank=1, timestamp=0.0,
                             interrupts={"ИРК": 5000}))
    b = ProfileBatch("jöb", [p], "nøde")
    assert decode_batch(encode_batch(b)).to_dataclasses() == b


def test_wire_rejects_bad_magic_and_future_version():
    data = encode_batch(ProfileBatch("j", [_profile()]))
    with pytest.raises(WireFormatError):
        decode_batch(b"XXXX" + data[4:])
    bumped = bytearray(data)
    bumped[4] = WIRE_VERSION + 1
    with pytest.raises(WireFormatError):
        decode_batch(bytes(bumped))
    with pytest.raises(WireFormatError):
        decode_batch(data[: len(data) // 2])


def test_wire_decode_into_foreign_tables_remaps_ids():
    batch = ProfileBatch("j", [_profile(r) for r in range(3)])
    data = encode_batch(batch)
    target = TraceTables()
    # pre-populate so ids cannot accidentally line up
    for s in ("zzz", "yyy", "xxx"):
        target.strings.intern(s)
    target.intern_stack(("zzz", "yyy"))
    out = decode_batch(data, tables=target)
    assert out.tables is target
    assert out.to_dataclasses() == batch


def test_encode_rejects_mixed_table_batches():
    a = profile_to_columnar(_profile(0))
    b = profile_to_columnar(_profile(1))        # different fresh tables
    with pytest.raises(ValueError):
        encode_batch(ColumnarBatch("j", [a, b], "n", a.tables))


def test_batch_fraction_rows_matches_per_profile():
    batch = to_columnar(ProfileBatch("j", [
        _profile(0, frames=(("m", "a"), ("m", "b", "c"))),
        IterationProfile(rank=1, iteration=0, group_id="g", iter_time=0.1),
        _profile(2, frames=(("m", "a", "a"),)),
    ]))
    t = batch.tables
    sids = np.concatenate([p.stack_id for p in batch.profiles])
    ws = np.concatenate([p.stack_weight for p in batch.profiles])
    off = np.cumsum([0] + [p.stack_id.shape[0] for p in batch.profiles])
    ids, vals, bounds = batch_fraction_rows(t, sids, ws, off)
    for i, p in enumerate(batch.profiles):
        got = dict(zip(ids[bounds[i]:bounds[i + 1]].tolist(),
                       vals[bounds[i]:bounds[i + 1]].tolist()))
        want = p.function_fraction_dict()
        assert set(got) == set(want)
        for k in want:
            assert got[k] == pytest.approx(want[k])


# -- table re-mapping ---------------------------------------------------------

def test_remap_is_incremental_and_value_preserving():
    src, dst = TraceTables(), TraceTables()
    p1 = profile_to_columnar(_profile(0), src)
    remap = TableRemap(src, dst)
    q1 = remap_profile(p1, remap)
    assert q1.tables is dst
    assert q1.to_dataclasses() == p1.to_dataclasses()
    # source keeps growing; remap only translates the tail
    p2 = profile_to_columnar(_profile(1, frames=(("new", "path"),)), src)
    q2 = remap_profile(p2, remap)
    assert q2.to_dataclasses() == p2.to_dataclasses()


# -- ColumnFlameGraph ---------------------------------------------------------

def test_column_flamegraph_mirrors_flamegraph():
    t = TraceTables()
    rows = [(t.intern_stack(("m", "a")), 3.0),
            (t.intern_stack(("m", "b", "c")), 1.0)]
    cfg = ColumnFlameGraph(t)
    cfg.add_id_rows(rows)
    fg = FlameGraph.from_rows(rows, t.stack_tuple)
    assert cfg.total == fg.total
    assert cfg.function_fractions() == fg.function_fractions()
    assert cfg.diff(fg) == {fn: 0.0 for fn in fg.function_fractions()}
    cfg2 = cfg.copy()
    cfg2.decay(0.5)
    fg.decay(0.5)
    assert cfg2.function_fractions() == fg.function_fractions()
    assert cfg2.to_flamegraph().counts == fg.counts
    assert cfg.total == 4.0                     # copy was independent


# -- service / agent integration ---------------------------------------------

def test_service_ingests_encoded_batches():
    svc = CentralService(window=20)
    cl = sc.SimCluster(n_ranks=4, seed=5, columnar=True)
    profiles = [p for _ in range(3) for p in cl.step()]
    data = encode_batch(ColumnarBatch("job-e", profiles, "n0", cl.tables))
    assert svc.ingest_encoded(data) == 12
    assert svc.ingested == 12
    st = svc.stats()
    assert st["ranks"] == 4


def test_sharded_service_ingests_encoded_batches_once_decoded():
    svc = ShardedService(n_shards=4, window=20)
    fleet = sc.MultiGroupSimCluster(n_groups=4, ranks_per_group=4, seed=5,
                                    columnar=True, samples_per_iter=50)
    profiles = [p for _ in range(2) for p in fleet.step()]
    data = encode_batch(ColumnarBatch("job-e", profiles, "n0", fleet.tables))
    assert svc.ingest_encoded(data) == 32
    # every group's state lives on exactly its routed shard
    for g in fleet.group_ids():
        owner = svc.shard_for(g)
        for s in svc.shards:
            assert (g in s._group_ranks) == (s is owner)
    # shards share the decode tables: no shard grew a private id space
    assert all(s.tables is svc.tables for s in svc.shards)


def test_agent_uploads_encoded_when_service_supports_it():
    svc = CentralService(window=20)
    agent = NodeAgent(AgentConfig(job_id="job-9", node_id="node-4"),
                      service=svc)
    cl = sc.SimCluster(n_ranks=2, seed=1)
    for p in cl.step():
        agent.submit(p)
    assert agent.flush() == 2
    assert agent.encoded_uploads == 1
    assert agent.bytes_uploaded > 0
    assert svc.ingested == 2


def test_agent_falls_back_to_dataclasses_for_legacy_service():
    class _Legacy:
        def __init__(self):
            self.profiles = []

        def ingest(self, p, job_id="job-0"):
            self.profiles.append(p)

    svc = _Legacy()
    agent = NodeAgent(AgentConfig(), service=svc)
    cl = sc.SimCluster(n_ranks=2, seed=1)
    originals = cl.step()
    for p in originals:
        agent.submit(p)
    assert agent.flush() == 2
    assert agent.encoded_uploads == 0
    assert svc.profiles == originals            # untouched dataclasses


def test_agent_reencode_failure_rebuffers():
    class _Flaky:
        def __init__(self):
            self.calls = 0

        def ingest_encoded(self, data):
            self.calls += 1
            raise ConnectionError("link down")

    svc = _Flaky()
    agent = NodeAgent(AgentConfig(), service=svc)
    cl = sc.SimCluster(n_ranks=2, seed=1)
    for p in cl.step():
        agent.submit(p)
    assert agent.flush() == 0
    assert agent.upload_failures == 1
    assert len(agent._buffer) == 2              # nothing lost


def test_agent_encodes_columnar_submissions_from_foreign_tables():
    svc = CentralService(window=20)
    agent = NodeAgent(AgentConfig(job_id="job-c"), service=svc)
    cl = sc.SimCluster(n_ranks=2, seed=1, columnar=True)
    for p in cl.step():                         # sim tables != agent tables
        agent.submit(p)
    assert agent.flush() == 2
    assert agent.encoded_uploads == 1
    assert svc.ingested == 2


def test_mixed_representation_group_still_diagnoses():
    """One rank uploads columnar, the rest legacy dataclasses — the group
    state stays coherent and the straggler is still diagnosed."""
    svc = CentralService(window=50)
    cl_obj = sc.SimCluster(n_ranks=8, seed=7)
    cl_col = sc.SimCluster(n_ranks=8, seed=7, columnar=True)
    cl_obj.add_fault(sc.nic_softirq(4, start=30))
    cl_col.add_fault(sc.nic_softirq(4, start=30))
    for it in range(90):
        obj_profiles = cl_obj.step()
        col_profiles = cl_col.step()
        for r in range(8):
            svc.ingest(col_profiles[r] if r % 2 else obj_profiles[r])
        if (it + 1) % 10 == 0:
            svc.process()
    svc.process()
    causes = {e.root_cause for e in svc.events}
    assert "nic_softirq_contention" in causes
    assert {e.straggler_rank for e in svc.events
            if e.root_cause == "nic_softirq_contention"} == {4}


# -- wire v3: dictionary sessions, negotiation, compressed columns ------------

def _batch_over(tables, profiles, job="job-s", node="node-s"):
    return ColumnarBatch(job, [profile_to_columnar(p, tables)
                               for p in profiles], node, tables)


def test_wire_v3_session_ships_tables_once():
    """Frame 2 of a session reuses frame 1's dictionary: it decodes to
    the same content a stateless frame would, but carries none of the
    already-shipped strings and is much smaller."""
    t = TraceTables()
    enc = WireEncoder(t)
    sessions = {}
    dec_tables = TraceTables()

    # a dictionary-heavy workload: 40 distinct stacks of long names
    deep = tuple(("main", f"layer_{i}_forward", f"op_{i}_fused_longname")
                 for i in range(40))
    b1 = _batch_over(t, [_profile(r, 0, frames=deep) for r in range(4)])
    out1 = decode_batch(bytes(enc.encode(b1)), dec_tables, sessions)
    assert out1.to_dataclasses() == b1.to_dataclasses()
    enc.commit()
    assert enc.seq == 1 and enc.nonce in sessions

    # same shape, next iteration: every string/stack is already shipped
    b2 = _batch_over(t, [_profile(r, 1, frames=deep) for r in range(4)])
    frame2 = bytes(enc.encode(b2))
    out2 = decode_batch(frame2, dec_tables, sessions)
    enc.commit()
    assert out2.to_dataclasses() == b2.to_dataclasses()
    stateless = encode_batch(b2, version=WIRE_VERSION)
    # the dictionary is gone from frame 2; what remains is event columns
    # (the full >=3x bytes-per-rank-iteration ratio is gated at fleet
    # scale by benchmarks/bench_fleet.py)
    assert len(frame2) < 0.75 * len(stateless)
    for token in (b"layer_7_forward", b"softmax", b"AllReduce"):
        assert token in stateless and token not in frame2

    # new strings appear -> only the table *tail* crosses the wire
    b3 = _batch_over(t, [_profile(0, 2, frames=(("main", "novel_fn"),))])
    frame3 = bytes(enc.encode(b3))
    out3 = decode_batch(frame3, dec_tables, sessions)
    enc.commit()
    assert out3.to_dataclasses() == b3.to_dataclasses()
    assert b"novel_fn" in frame3 and b"layer_7_forward" not in frame3


def test_wire_v3_reencode_before_commit_is_byte_identical():
    """The §7 retry contract: a failed upload re-encoded before commit()
    produces the identical bytes (same nonce, seq, watermarks)."""
    t = TraceTables()
    enc = WireEncoder(t)
    sessions = {}
    dec = TraceTables()
    b1 = _batch_over(t, [_profile(0, 0)])
    decode_batch(bytes(enc.encode(b1)), dec, sessions)
    enc.commit()
    b2 = _batch_over(t, [_profile(1, 1)])
    first = bytes(enc.encode(b2))
    again = bytes(enc.encode(b2))          # retry: no commit in between
    assert first == again
    # and the retried frame still decodes mid-session
    out = decode_batch(again, dec, sessions)
    assert out.to_dataclasses() == b2.to_dataclasses()


def test_wire_v3_session_gap_detection_and_reset():
    t = TraceTables()
    enc = WireEncoder(t)
    sessions = {}
    dec = TraceTables()
    decode_batch(bytes(enc.encode(_batch_over(t, [_profile(0, 0)]))),
                 dec, sessions)
    enc.commit()
    skipped = _batch_over(t, [_profile(0, 1)])
    enc.encode(skipped)
    enc.commit()                            # frame never delivered
    late = bytes(enc.encode(_batch_over(t, [_profile(0, 2)])))
    with pytest.raises(WireFormatError):    # sequence gap detected
        decode_batch(late, dec, sessions)
    # mid-session frame against a decoder with no session state at all
    with pytest.raises(WireFormatError):
        decode_batch(late, TraceTables(), {})
    with pytest.raises(WireFormatError):
        decode_batch(late, TraceTables(), None)
    # sender resets: next frame opens a fresh self-contained session
    old_nonce = enc.nonce
    enc.reset()
    assert enc.nonce != old_nonce and enc.seq == 0
    reopened = _batch_over(t, [_profile(0, 3)])
    out = decode_batch(bytes(enc.encode(reopened)), dec, sessions)
    assert out.to_dataclasses() == reopened.to_dataclasses()


def test_wire_v3_buffer_rotation_when_views_pin_the_frame():
    """An in-process receiver holding np.frombuffer views into the last
    frame pins the encoder's bytearray; the next encode() rotates to a
    fresh buffer instead of corrupting the views."""
    t = TraceTables()
    enc = WireEncoder(t)
    b1 = _batch_over(t, [_profile(0, 0)])
    view = enc.encode(b1)                   # hold the memoryview
    enc.commit()
    assert enc.buf_rotations == 0
    frame2 = enc.encode(_batch_over(t, [_profile(0, 1)]))
    assert enc.buf_rotations == 1
    assert bytes(view[:4]) == b"SYTC"       # old frame bytes intact
    view.release()
    frame2.release()                        # nothing pins the new buffer now
    enc.commit()
    enc.encode(_batch_over(t, [_profile(0, 2)])).release()
    assert enc.buf_rotations == 1           # released -> buffer reused


def test_wire_encoder_refuses_downlevel_and_foreign_tables():
    t = TraceTables()
    with pytest.raises(WireFormatError):
        WireEncoder(t, version=2)
    with pytest.raises(WireFormatError):
        WireEncoder(t, version=WIRE_VERSION + 1)
    enc = WireEncoder(t)
    foreign = _batch_over(TraceTables(), [_profile(0)])
    with pytest.raises(ValueError):
        enc.encode(foreign)


def test_wire_negotiation_matrix_v1_v2_v3():
    """Every supported version round-trips the same batch; v1 refuses
    (never silently drops) extended OS counters, v2+ carry them."""
    plain = ProfileBatch("j", [
        IterationProfile(rank=r, iteration=1, group_id="g", iter_time=0.1,
                         cpu_samples=[StackSample(rank=r, timestamp=0.5,
                                                  frames=("m", "f"),
                                                  weight=2, kind="cpu")],
                         os_signals=OSSignals(rank=r, timestamp=0.6,
                                              interrupts={"LOC": 10}))
        for r in range(3)], "n")
    for v in range(WIRE_MIN_VERSION, WIRE_VERSION + 1):
        data = encode_batch(plain, version=v)
        assert data[4] == v                 # least-significant byte of u16
        assert decode_batch(data).to_dataclasses() == plain

    extended = ProfileBatch("j", [IterationProfile(
        rank=0, iteration=0, group_id="g", iter_time=0.1,
        os_signals=OSSignals(rank=0, timestamp=0.0, major_faults=123))], "n")
    with pytest.raises(WireFormatError):
        encode_batch(extended, version=1)
    for v in (2, WIRE_VERSION):
        assert decode_batch(encode_batch(extended, version=v)) \
            .to_dataclasses() == extended

    with pytest.raises(WireFormatError):
        encode_batch(plain, version=0)
    with pytest.raises(WireFormatError):
        encode_batch(plain, version=WIRE_VERSION + 1)


def test_wire_v3_extreme_columns_round_trip():
    """Delta+varint integer columns at the wraparound edge and
    bit-pattern float columns: zero-length, single-event, and max-delta
    (consecutive values 2**63 apart wrap int64 and cumsum back exactly)."""
    hi, lo = (1 << 62), -(1 << 62)
    p = IterationProfile(
        rank=0, iteration=1 << 40, group_id="g", iter_time=1e-300,
        collectives=[
            CollectiveEvent(rank=0, group_id="g", op="P2P", entry=-1e12,
                            exit=1e12, nbytes=lo, instance=hi, seq=lo),
            CollectiveEvent(rank=0, group_id="g", op="P2P", entry=1e-12,
                            exit=5e300, nbytes=hi, instance=lo, seq=hi)])
    single = ProfileBatch("j", [IterationProfile(
        rank=1 << 20, iteration=0, group_id="g", iter_time=0.0,
        kernel_events=[KernelEvent(rank=1 << 20, name="k", start=-0.0,
                                   duration=float("1e308"))])], "n")
    for batch in (ProfileBatch("j", [p], "n"), single,
                  ProfileBatch("j", [], "n")):
        assert decode_batch(encode_batch(batch, version=WIRE_VERSION)) \
            .to_dataclasses() == batch


def test_encode_into_byte_identical_and_overflow_safe():
    """``encode_into`` must produce exactly the bytes ``encode`` would
    (the in-ring and on-pipe layouts are one layout), and an overflow
    must leave the session able to re-encode the identical frame."""
    t = TraceTables()
    a, b = WireEncoder(t), WireEncoder(t)
    b._nonce = a._nonce                     # same session identity
    buf = memoryview(bytearray(1 << 16))
    for it in range(3):
        batch = _batch_over(t, [_profile(r, it) for r in range(3)])
        ref = bytes(a.encode(batch))
        n = b.encode_into(batch, buf)
        assert bytes(buf[:n]) == ref
        a.commit()
        b.commit()
    # too-small target: BufferError, nothing staged as delivered, and
    # the fallback re-encode is byte-identical to the direct encode
    batch = _batch_over(t, [_profile(9, 9)])
    with pytest.raises(BufferError):
        b.encode_into(batch, memoryview(bytearray(8)))
    assert bytes(b.encode(batch)) == bytes(a.encode(batch))


def test_decode_detach_survives_buffer_recycling():
    """``detach=True`` decouples every decoded column from the payload
    buffer: scribbling over the buffer right after decode (what a ring
    release permits the producer to do) must not alter the profiles."""
    t = TraceTables()
    enc = WireEncoder(t)
    batch = _batch_over(t, [_profile(r, 1) for r in range(2)])
    raw = bytearray(bytes(enc.encode(batch)))
    svc_tables, sessions = TraceTables(), {}
    got = decode_batch(memoryview(raw), tables=svc_tables,
                       sessions=sessions, detach=True)
    want = [(p.stack_ts.copy(), p.kern_dur.copy(), p.coll_nbytes.copy(),
             p.coll_entry.copy()) for p in got.profiles]
    raw[:] = b"\xff" * len(raw)             # producer recycles the slot
    for p, (ts, kd, nb, ce) in zip(got.profiles, want):
        assert np.array_equal(p.stack_ts, ts)
        assert np.array_equal(p.kern_dur, kd)
        assert np.array_equal(p.coll_nbytes, nb)
        assert np.array_equal(p.coll_entry, ce)
    # OS thunks materialize from detached columns too
    sig = got.profiles[0].os_signals
    assert sig is not None and sig.interrupts
