"""Per-architecture smoke tests: reduced same-family config, one forward +
train step + decode step on CPU, asserting output shapes and finite values.
(The FULL configs are exercised only via the dry-run, per the assignment.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.optim import make_schedule
from repro.train import init_train_state, make_train_step, make_serve_step

ARCHS = configs.list_archs()


def _batch(cfg, b=2, s=64, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    batch = {}
    if cfg.is_enc_dec:
        batch["embeds"] = jax.random.normal(
            k1, (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32) * 0.02
        batch["tokens"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    elif cfg.embeds_as_input:
        batch["embeds"] = jax.random.normal(k1, (b, s, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.tiny(arch)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, make_schedule("cosine", peak_lr=1e-3)))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(jnp.asarray(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = configs.tiny(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    b = 2
    cache, _ = model.init_cache(b, 64)
    if cfg.is_enc_dec:
        from repro.models import whisper
        emb = jnp.ones((b, cfg.encoder_seq_len, cfg.d_model), jnp.float32) * 0.02
        cache = whisper.prime_cross_cache(params, cache, emb, cfg)
    serve = jax.jit(make_serve_step(model))
    if cfg.embeds_as_input and not cfg.is_enc_dec:
        tok = jnp.ones((b, 1, cfg.d_model), jnp.float32)
    else:
        tok = jnp.ones((b, 1), jnp.int32)
    logits, new_cache = serve(params, cache, tok, jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache mutated
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b", "mamba2-370m"])
def test_decode_matches_incremental_prefill(arch):
    """Decoding two tokens sequentially keeps logits finite and cache
    positions advance (sanity of KV/SSM state threading)."""
    cfg = dataclasses.replace(configs.tiny(arch), param_dtype="float32",
                              compute_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    cache, _ = model.init_cache(1, 16)
    serve = jax.jit(make_serve_step(model))
    logits = []
    for pos in range(3):
        tok = jnp.array([[pos + 1]], jnp.int32)
        lg, cache = serve(params, cache, tok, jnp.array([pos], jnp.int32))
        logits.append(lg)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in logits)


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = configs.get("qwen2-0.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (24, 896, 14, 2, 4864, 151936)
    assert c.qkv_bias
    c = configs.get("minicpm-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (40, 2304, 36, 36, 5760, 122753)
    c = configs.get("gemma-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.head_dim) == (18, 2048, 8, 1, 16384, 256000, 256)
    assert c.activation == "gelu"
    c = configs.get("qwen3-4b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (36, 2560, 32, 8, 9728, 151936)
    assert c.qk_norm
    c = configs.get("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size,
            c.ssm_state_size) == (54, 2560, 32, 10240, 32000, 64)
    assert c.shared_attention
    c = configs.get("qwen3-moe-30b-a3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.num_experts_per_tok) == \
        (48, 2048, 32, 4, 768, 151936, 128, 8)
    c = configs.get("mixtral-8x22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.num_experts_per_tok) == \
        (56, 6144, 48, 8, 16384, 32768, 8, 2)
    assert c.sliding_window == 4096
    c = configs.get("qwen2-vl-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (28, 3584, 28, 4, 18944, 152064)
    assert c.rope_type == "mrope"
    c = configs.get("mamba2-370m")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state_size) == \
        (48, 1024, 50280, 128)
    c = configs.get("whisper-base")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads,
            c.d_ff, c.vocab_size) == (6, 6, 512, 8, 2048, 51865)


def test_long_context_applicability():
    assert not configs.shape_applicable("qwen2-0.5b", "long_500k")
    assert not configs.shape_applicable("whisper-base", "long_500k")
    assert configs.shape_applicable("mixtral-8x22b", "long_500k")  # SWA
    assert configs.shape_applicable("mamba2-370m", "long_500k")
    assert configs.shape_applicable("zamba2-2.7b", "long_500k")
    cells = configs.all_cells()
    assert len(cells) == 40
    assert sum(1 for *_names, ok in cells if ok) == 33
