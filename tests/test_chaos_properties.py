"""Hypothesis properties of the chaos harness.

Invariants (ISSUE acceptance):
  * a schedule regenerates bit-identically from its seed (pure data)
  * the same seeded storm is event-for-event identical across the
    legacy/streaming/columnar/sharded service paths
  * arbitrary seeds never crash a storm run — they only vary it
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chaos import ChaosRunner, ChaosSchedule  # noqa: E402

settings.register_profile("chaos", max_examples=5, deadline=None)
settings.load_profile("chaos")

_LAYOUT = [[0, 1, 2, 3, 4, 5], [6, 7, 8, 9, 10, 11],
           [12, 13, 14, 15, 16, 17]]


def _event_key(ev):
    """Comparable identity for a ChaosEvent: the attached Fault carries
    effect lambdas, which never compare equal across instances."""
    return (ev.iteration, ev.kind, ev.name, ev.group_index, ev.rank)


def _generate(seed):
    return ChaosSchedule.generate(seed, _LAYOUT, n_faults=2, horizon=60,
                                  n_dropouts=1, n_mitigation_blips=1)


@given(seed=st.integers(0, 2**32 - 1))
def test_schedule_regenerates_identically(seed):
    a, b = _generate(seed), _generate(seed)
    assert [_event_key(e) for e in a.events] == \
        [_event_key(e) for e in b.events]
    assert a.true_roots == b.true_roots
    assert a.dropout_ranks() == b.dropout_ranks()


@given(seed=st.integers(0, 10_000))
def test_same_seed_same_events_across_paths(seed):
    sched = _generate(seed)
    tuples = {}
    for path in ("legacy", "streaming", "columnar", "sharded"):
        rep = ChaosRunner(sched, path).run()
        tuples[path] = rep.event_tuples
    assert tuples["legacy"] == tuples["streaming"] \
        == tuples["columnar"] == tuples["sharded"], tuples


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       n_faults=st.integers(1, 3),
       flap_prob=st.floats(0.0, 1.0))
def test_arbitrary_storms_never_crash(seed, n_faults, flap_prob):
    sched = ChaosSchedule.generate(seed, _LAYOUT, n_faults=n_faults,
                                   horizon=60, flap_prob=flap_prob,
                                   n_dropouts=1)
    rep = ChaosRunner(sched, "streaming").run()
    # sanity, not scoring: the report is internally consistent
    assert 0.0 <= rep.flip_rate <= 1.0
    assert set(rep.localized) == {(r.group_index, r.rank)
                                  for r in sched.true_roots}
    assert len(rep.event_tuples) == len(rep.events)
