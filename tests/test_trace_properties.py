"""Hypothesis property tests for the columnar wire codec and the
to_columnar/to_dataclasses adapters: decode(encode(batch)) == batch over
arbitrary batches — empty profiles, unicode frame names, multi-group
batches, extreme ints/floats."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.events import (CollectiveEvent, IterationProfile, KernelEvent,
                               OSSignals, ProfileBatch, StackSample)
from repro.core.trace import (TraceTables, decode_batch, encode_batch,
                              to_columnar, to_dataclasses)

settings.register_profile("trace", max_examples=40, deadline=None)
settings.load_profile("trace")

_name = st.text(min_size=1, max_size=12)
_floats = st.floats(allow_nan=False, allow_infinity=False,
                    min_value=-1e12, max_value=1e12)
_i64 = st.integers(min_value=-(1 << 62), max_value=1 << 62)
_small = st.integers(min_value=0, max_value=1 << 40)


@st.composite
def _profiles(draw):
    rank = draw(st.integers(0, 1 << 20))
    group = draw(_name)
    samples = draw(st.lists(st.builds(
        StackSample, rank=st.just(rank), timestamp=_floats,
        frames=st.lists(_name, min_size=0, max_size=5).map(tuple),
        weight=_i64, kind=_name), max_size=6))
    kernels = draw(st.lists(st.builds(
        KernelEvent, rank=st.just(rank), name=_name, start=_floats,
        duration=_floats, stream=_i64), max_size=5))
    colls = draw(st.lists(st.builds(
        CollectiveEvent, rank=st.just(rank), group_id=_name, op=_name,
        entry=_floats, exit=_floats, nbytes=_i64, device_duration=_floats,
        instance=_i64, seq=_i64), max_size=4))
    sig = draw(st.none() | st.builds(
        OSSignals, rank=st.just(rank), timestamp=_floats,
        interrupts=st.dictionaries(_name, _small, max_size=4),
        softirq_residency=st.dictionaries(_name, _floats, max_size=3),
        sched_latency_p99=_floats, numa_migrations=_small,
        cpu_steal=_floats,
        # extended (SYTC-v2) node counters
        major_faults=_small, cpu_freq_mhz=_floats, pcie_replays=_small,
        ecc_remapped_rows=_small, numa_remote_ratio=_floats))
    return IterationProfile(
        rank=rank, iteration=draw(st.integers(0, 1 << 40)), group_id=group,
        iter_time=draw(_floats), cpu_samples=samples, kernel_events=kernels,
        collectives=colls, os_signals=sig)


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=5),
                 node_id=_name))
def test_wire_codec_round_trip_property(batch):
    assert decode_batch(encode_batch(batch)).to_dataclasses() == batch


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=4),
                 node_id=_name))
def test_adapter_round_trip_property(batch):
    assert to_dataclasses(to_columnar(batch)) == batch


@given(st.lists(_profiles(), min_size=1, max_size=4))
def test_decode_into_shared_tables_property(profiles):
    """Re-mapping into a growing service table set never changes values."""
    tables = TraceTables()
    tables.strings.intern("pre")
    for p in profiles:
        out = decode_batch(encode_batch(ProfileBatch("j", [p])),
                           tables=tables)
        assert out.to_dataclasses().profiles[0] == p


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=4),
                 node_id=_name))
def test_wire_v1_negotiation_property(batch):
    """Downlevel v1 encoding either round-trips exactly (no extended OS
    counters anywhere in the batch) or is refused — never silently lossy."""
    from repro.core.trace import WireFormatError
    extended = any(
        p.os_signals is not None and any(
            (p.os_signals.major_faults, p.os_signals.cpu_freq_mhz,
             p.os_signals.pcie_replays, p.os_signals.ecc_remapped_rows,
             p.os_signals.numa_remote_ratio))
        for p in batch.profiles)
    if extended:
        with pytest.raises(WireFormatError):
            encode_batch(batch, version=1)
    else:
        assert decode_batch(encode_batch(batch, version=1)
                            ).to_dataclasses() == batch
