"""Hypothesis property tests for the columnar wire codec and the
to_columnar/to_dataclasses adapters: decode(encode(batch)) == batch over
arbitrary batches — empty profiles, unicode frame names, multi-group
batches, extreme ints/floats."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.events import (CollectiveEvent, IterationProfile, KernelEvent,
                               OSSignals, ProfileBatch, StackSample)
from repro.core.trace import (TraceTables, decode_batch, encode_batch,
                              to_columnar, to_dataclasses)

settings.register_profile("trace", max_examples=40, deadline=None)
settings.load_profile("trace")

_name = st.text(min_size=1, max_size=12)
_floats = st.floats(allow_nan=False, allow_infinity=False,
                    min_value=-1e12, max_value=1e12)
_i64 = st.integers(min_value=-(1 << 62), max_value=1 << 62)
_small = st.integers(min_value=0, max_value=1 << 40)


@st.composite
def _profiles(draw):
    rank = draw(st.integers(0, 1 << 20))
    group = draw(_name)
    samples = draw(st.lists(st.builds(
        StackSample, rank=st.just(rank), timestamp=_floats,
        frames=st.lists(_name, min_size=0, max_size=5).map(tuple),
        weight=_i64, kind=_name), max_size=6))
    kernels = draw(st.lists(st.builds(
        KernelEvent, rank=st.just(rank), name=_name, start=_floats,
        duration=_floats, stream=_i64), max_size=5))
    colls = draw(st.lists(st.builds(
        CollectiveEvent, rank=st.just(rank), group_id=_name, op=_name,
        entry=_floats, exit=_floats, nbytes=_i64, device_duration=_floats,
        instance=_i64, seq=_i64), max_size=4))
    sig = draw(st.none() | st.builds(
        OSSignals, rank=st.just(rank), timestamp=_floats,
        interrupts=st.dictionaries(_name, _small, max_size=4),
        softirq_residency=st.dictionaries(_name, _floats, max_size=3),
        sched_latency_p99=_floats, numa_migrations=_small,
        cpu_steal=_floats,
        # extended (SYTC-v2) node counters
        major_faults=_small, cpu_freq_mhz=_floats, pcie_replays=_small,
        ecc_remapped_rows=_small, numa_remote_ratio=_floats))
    return IterationProfile(
        rank=rank, iteration=draw(st.integers(0, 1 << 40)), group_id=group,
        iter_time=draw(_floats), cpu_samples=samples, kernel_events=kernels,
        collectives=colls, os_signals=sig)


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=5),
                 node_id=_name))
def test_wire_codec_round_trip_property(batch):
    assert decode_batch(encode_batch(batch)).to_dataclasses() == batch


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=4),
                 node_id=_name))
def test_adapter_round_trip_property(batch):
    assert to_dataclasses(to_columnar(batch)) == batch


@given(st.lists(_profiles(), min_size=1, max_size=4))
def test_decode_into_shared_tables_property(profiles):
    """Re-mapping into a growing service table set never changes values."""
    tables = TraceTables()
    tables.strings.intern("pre")
    for p in profiles:
        out = decode_batch(encode_batch(ProfileBatch("j", [p])),
                           tables=tables)
        assert out.to_dataclasses().profiles[0] == p


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=4),
                 node_id=_name))
def test_wire_v1_negotiation_property(batch):
    """Downlevel v1 encoding either round-trips exactly (no extended OS
    counters anywhere in the batch) or is refused — never silently lossy."""
    from repro.core.trace import WireFormatError
    extended = any(
        p.os_signals is not None and any(
            (p.os_signals.major_faults, p.os_signals.cpu_freq_mhz,
             p.os_signals.pcie_replays, p.os_signals.ecc_remapped_rows,
             p.os_signals.numa_remote_ratio))
        for p in batch.profiles)
    if extended:
        with pytest.raises(WireFormatError):
            encode_batch(batch, version=1)
    else:
        assert decode_batch(encode_batch(batch, version=1)
                            ).to_dataclasses() == batch


# -- wire v3: compressed columns, versions, dictionary sessions ---------------

_i64_full = st.integers(min_value=-(1 << 63), max_value=(1 << 63) - 1)
_any_f64 = st.floats(allow_nan=True, allow_infinity=True, width=64)


@given(st.lists(_i64_full, max_size=64))
def test_v3_int_column_round_trip_property(values):
    """Delta+varint integer columns are lossless over the full int64
    domain — zero-length, single-value, and max-delta neighbours (2**63
    apart) where the delta wraps and the cumsum wraps back exactly."""
    import numpy as np

    from repro.core.trace import _Reader, _Writer, _put_ivar, _read_ivar
    w = _Writer()
    _put_ivar(w, np.array(values, dtype=np.int64))
    out = _read_ivar(_Reader(bytes(w.buf)))
    assert out.tolist() == values


@given(st.lists(_any_f64, max_size=64))
def test_v3_float_column_round_trip_property(values):
    """Xor-delta float columns are bit-lossless — infinities, both
    zeros, and NaN payload bits all survive."""
    import numpy as np

    from repro.core.trace import _Reader, _Writer, _put_fvar, _read_fvar
    a = np.array(values, dtype=np.float64)
    w = _Writer()
    _put_fvar(w, a)
    out = _read_fvar(_Reader(bytes(w.buf)))
    assert out.view(np.uint64).tolist() == a.view(np.uint64).tolist()


@given(st.builds(ProfileBatch, job_id=_name,
                 profiles=st.lists(_profiles(), max_size=4),
                 node_id=_name),
       st.sampled_from((2, 3)))
def test_wire_negotiation_v2_v3_property(batch, version):
    """v2 and v3 stateless frames round-trip any batch (both carry the
    extended OS counters); the decoder accepts every emitted version."""
    assert decode_batch(encode_batch(batch, version=version)
                        ).to_dataclasses() == batch


@given(st.lists(st.lists(_profiles(), max_size=3), min_size=1, max_size=4))
def test_wire_v3_session_round_trip_property(batches):
    """A dictionary-delta session round-trips an arbitrary sequence of
    batches: each frame ships only the table tail, every decode matches,
    and re-encoding any frame before commit is byte-identical."""
    from repro.core.trace import ColumnarBatch, WireEncoder, profile_to_columnar
    tables = TraceTables()
    enc = WireEncoder(tables)
    sessions = {}
    dec_tables = TraceTables()
    for profiles in batches:
        batch = ColumnarBatch(
            "j", [profile_to_columnar(p, tables) for p in profiles],
            "n", tables)
        first = bytes(enc.encode(batch))
        assert bytes(enc.encode(batch)) == first    # pre-commit retry
        out = decode_batch(first, dec_tables, sessions)
        enc.commit()
        assert out.to_dataclasses() == batch.to_dataclasses()
