"""Algorithm 1 (adaptive hybrid FP+DWARF unwinding) behaviors."""
import random
import threading

import pytest

from repro.core.unwind import (Binary, FunctionDef, HybridUnwinder, Marker,
                               MarkerMap, SimProcess, SimThread, synth_binary)
from repro.core.unwind.dwarf import DwarfUnwinder, preprocess_eh_frame
from repro.core.unwind.fp import unwind_fp_only


def _setup(omit=0.3, n=200, seed=0):
    b = synth_binary("libx", n_functions=n, omit_fp_fraction=omit, seed=seed)
    proc = SimProcess()
    proc.mmap_binary(b)
    uw = HybridUnwinder()
    uw.register_binary(b)
    return b, proc, uw


def _chain(b, rng, depth):
    return [(b, rng.choice(b.functions)) for _ in range(depth)]


def test_hybrid_recovers_full_stack():
    b, proc, uw = _setup()
    rng = random.Random(0)
    for i in range(100):
        t = SimThread(proc, random.Random(i))
        t.call_chain(_chain(b, rng, rng.randrange(5, 25)))
        names, truth = uw.unwind_symbolized_truthcheck(t)
        assert names == truth, (names, truth)


def test_fp_only_truncates_at_omitted_frame():
    b, proc, uw = _setup(omit=1.0)  # every function omits FP
    t = SimThread(proc, random.Random(1))
    t.call_chain(_chain(b, random.Random(2), 15))
    stack = unwind_fp_only(t)
    assert len(stack) <= 2  # leaf only (garbage FP breaks immediately)


def test_fp_only_works_on_go_like_binary():
    b, proc, uw = _setup(omit=0.0)  # Go-style: FP always preserved
    t = SimThread(proc, random.Random(1))
    t.call_chain(_chain(b, random.Random(2), 15))
    stack = unwind_fp_only(t)
    assert len(stack) == 15


def test_markers_converge_and_match_compile_flags():
    b, proc, uw = _setup(omit=0.4, n=100)
    rng = random.Random(3)
    for i in range(300):
        t = SimThread(proc, random.Random(i))
        t.call_chain(_chain(b, rng, 12))
        uw.unwind(t)
    # marker soundness: FP-marked => preserves FP; omits-FP => DWARF-marked.
    # (A preserving function CAN be DWARF-marked from the chain-root edge
    # case — Algorithm 1 marks dwarf on any validation failure — which is
    # safe: DWARF still unwinds it correctly, just costs a bisect.)
    checked = fp_marked = 0
    for f in b.functions:
        m = uw.markers.get(b.build_id, f.offset)
        if m is Marker.UNMARKED:
            continue
        checked += 1
        if f.omits_fp:
            assert m is Marker.DWARF, f.name
        if m is Marker.FP:
            fp_marked += 1
            assert not f.omits_fp, f.name
    assert checked > 50 and fp_marked > 20


def test_steady_state_cost_is_fp_dominated():
    """§3.3 cost claim: after convergence, per-sample cost ~ pure FP when
    most functions preserve FP."""
    b, proc, uw = _setup(omit=0.2)
    rng = random.Random(4)
    for i in range(200):
        t = SimThread(proc, random.Random(i))
        t.call_chain(_chain(b, rng, 20))
        uw.unwind(t)
    s = uw.stats
    assert s.fp_fraction > 0.7
    # validations only happen on first encounters (bounded by function count)
    assert s.validations <= len(b.functions) + 50


def test_validation_rejects_garbage_fp():
    """A leaf that omits FP must fail ValidateCallerPC and go DWARF."""
    b = Binary("single", "b1d" * 13 + "0", [
        FunctionDef("root", 0x1000, 256, omits_fp=False),
        FunctionDef("leaf_omits", 0x2000, 256, omits_fp=True),
    ], 0x3000)
    proc = SimProcess()
    proc.mmap_binary(b)
    uw = HybridUnwinder()
    uw.register_binary(b)
    t = SimThread(proc, random.Random(5))
    t.call_chain([(b, b.functions[0]), (b, b.functions[1])])
    names, truth = uw.unwind_symbolized_truthcheck(t)
    assert names == truth == ("leaf_omits", "root")
    assert uw.markers.get(b.build_id, 0x2000) is Marker.DWARF
    assert uw.stats.validation_failures >= 1


def test_fde_bisect_is_logarithmic():
    b = synth_binary("liby", n_functions=1000, omit_fp_fraction=0.5, seed=7)
    table = preprocess_eh_frame(b)
    assert len(table) == 1000
    n_lookups = 64
    for f in b.functions[:n_lookups]:
        fde = table.lookup(f.offset + 8)
        assert fde is not None and fde.start == f.offset
    assert table.bisect_iterations <= n_lookups * (1000).bit_length()


def test_complex_fde_userspace_fallback():
    b = Binary("cx", "c" * 40, [
        FunctionDef("root", 0x1000, 256, omits_fp=False),
        FunctionDef("weird", 0x2000, 256, omits_fp=True, complex_fde=True),
        FunctionDef("leaf", 0x3000, 256, omits_fp=False),
    ], 0x4000)
    proc = SimProcess()
    proc.mmap_binary(b)
    uw = HybridUnwinder()
    uw.register_binary(b)
    t = SimThread(proc, random.Random(6))
    t.call_chain([(b, b.functions[0]), (b, b.functions[1]),
                  (b, b.functions[2])])
    names, truth = uw.unwind_symbolized_truthcheck(t)
    assert names == truth
    assert uw.dwarf.complex_fallbacks >= 1


def test_dlopen_binary_unknown_until_registered():
    """dlopen'd library: frames unresolvable until the 5 s maps-poll
    registers it; afterwards the same sample unwinds fully (§4)."""
    b1 = synth_binary("base", n_functions=50, omit_fp_fraction=0.0, seed=8)
    b2 = synth_binary("plugin", n_functions=50, omit_fp_fraction=1.0, seed=9)
    proc = SimProcess()
    proc.mmap_binary(b1)
    proc.mmap_binary(b2)  # mapped but NOT registered with the unwinder
    uw = HybridUnwinder()
    uw.register_binary(b1)
    t = SimThread(proc, random.Random(7))
    t.call_chain([(b1, b1.functions[0]), (b2, b2.functions[0]),
                  (b1, b1.functions[1])])
    names, truth = uw.unwind_symbolized_truthcheck(t)
    assert names != truth  # truncated inside the unregistered plugin
    uw.register_binary(b2)  # maps poll found it
    names2, truth2 = uw.unwind_symbolized_truthcheck(t)
    assert names2 == truth2


def test_jit_functions_marked_dwarf_conservatively():
    b = Binary("jit", "d" * 40, [
        FunctionDef("jitted", 0x1000, 256, omits_fp=False, is_jit=True),
    ], 0x2000)
    uw = HybridUnwinder()
    uw.register_binary(b)
    assert uw.markers.get(b.build_id, 0x1000) is Marker.DWARF


def test_marker_cas_concurrent_convergence():
    mm = MarkerMap()
    results = []

    def racer(val):
        results.append(mm.compare_and_swap("bid", 0x10, Marker.UNMARKED, val))

    ts = [threading.Thread(target=racer,
                           args=(Marker.FP if i % 2 else Marker.DWARF,))
          for i in range(16)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    final = mm.get("bid", 0x10)
    assert all(r is final for r in results)  # all racers converged
