"""End-to-end reproduction of the paper's five §5.4 case studies:
SimCluster fault injection -> agent-equivalent profiles -> CentralService
-> layered diagnosis, asserting the exact root cause (and straggler rank
where the paper reports one)."""
import pytest

from repro.core import simcluster as sc
from repro.core.service import CentralService
from repro.ft import MitigationPlanner


def _run(fault, robust=False, baseline_iters=30, fault_iters=60, seed=7):
    svc = CentralService(window=50, robust_detector=robust)
    cl = sc.SimCluster(n_ranks=8, seed=seed)
    cl.run(svc, baseline_iters)
    pre = len(svc.events)
    if fault is not None:
        cl.add_fault(fault)
    cl.run(svc, fault_iters)
    return svc, svc.events[pre:]


def test_case1_gpu_thermal_throttle():
    svc, events = _run(sc.thermal_throttle(0, start=30))
    assert events
    e = events[0]
    assert e.root_cause == "gpu_uniform_slowdown"
    assert e.category == "gpu_hardware"
    assert e.straggler_rank == 0
    # evidence shows the uniform ratio pattern of Fig 6
    ratios = e.verdict.evidence["per_kernel_ratio"]
    assert all(r > 1.03 for r in ratios.values())


def test_case2_nic_softirq_contention():
    svc, events = _run(sc.nic_softirq(4, start=30))
    assert events
    e = events[0]
    assert e.root_cause == "nic_softirq_contention"
    assert e.category == "os_interference"
    assert e.straggler_rank == 4
    # the full interrupt chain is visible in the hot deltas (Fig 7)
    hot = e.verdict.evidence["hot_deltas"]
    assert any("net_rx_action" in f for f in hot)
    assert any("napi" in f for f in hot)


def test_case3_vfs_dentry_lock_contention():
    svc, events = _run(sc.vfs_lock_contention([2, 3], start=30), robust=True)
    assert events
    causes = {e.root_cause for e in events}
    assert causes == {"vfs_dentry_lock_contention"}
    flagged = {e.straggler_rank for e in events if e.straggler_rank is not None}
    assert flagged <= {2, 3} and flagged


def test_case4_logging_overhead_via_temporal_baseline():
    svc, events = _run(sc.logging_overhead(start=30))
    assert events
    e = events[0]
    assert e.root_cause == "logging_overhead"
    assert e.category == "software"
    assert e.straggler_rank is None            # uniform: no straggler fired


def test_case5_storage_io_bottleneck():
    svc, events = _run(sc.io_bottleneck(start=30))
    assert events
    e = events[0]
    assert e.root_cause == "storage_io_bottleneck"
    assert e.straggler_rank is None


def test_healthy_cluster_is_quiet():
    svc, events = _run(None)
    assert events == []


def test_diagnosis_latency_is_fast():
    """The paper's headline: ~10 min vs days.  Our analysis pass itself is
    sub-second; detection needs <= ~1 window of iterations."""
    svc, events = _run(sc.nic_softirq(4, start=30))
    assert events[0].diagnosis_latency_s < 5.0


def test_mitigation_consumes_diagnoses():
    svc, events = _run(sc.nic_softirq(4, start=30))
    planner = MitigationPlanner(straggler_patience=2)
    acts = []
    for e in events:
        acts.extend(planner.on_diagnosis(e))
    kinds = [a.kind for a in acts]
    assert "observe" in kinds
    if len(events) >= 2:
        assert "restart_elastic" in kinds
        plan = next(a.plan for a in acts if a.kind == "restart_elastic")
        assert plan.new_data_axis < 16 and plan.feasible


def test_comm_registration_without_symbols():
    """The SimCluster hands out packed comm snapshots; the codec sniffs
    the version and recovers group identity (§3.2)."""
    from repro.core.collective import CommStructCodec
    cl = sc.SimCluster(n_ranks=8)
    for r in range(8):
        blob = cl.comm_snapshots(r)[0]
        info = CommStructCodec.sniff(blob)
        assert info is not None
        assert info.rank == r and info.n_ranks == 8
        assert info.group_id == cl.group_id
