"""Hierarchical pod aggregation tier (§5 scale-out): digest merge
semantics, and the facade-equivalence contract — ``process()`` output,
published snapshots and the ``audit()`` walk are event-for-event
identical to the flat ``ShardedService`` with ``n_shards == n_pods``."""
import numpy as np
import pytest

from repro.core import simcluster as sc
from repro.core.aggregate import merge_stack_columns
from repro.core.pod import (PodAggregator, PodDigest, PodTierService,
                            merge_digests)
from repro.core.sharded import ShardedService
from repro.core.trace import ColumnarBatch, WireEncoder, encode_batch

LAYOUT = [[0, 1, 2, 3, 4, 5, 6, 7], [7, 8, 9, 10, 11, 12, 13, 14]]


def _drive(svc, *, session: bool = False, seed: int = 3,
           layout=LAYOUT, samples: int = 120, iters: int = (30, 30),
           fault_rank: int = 2):
    """Cascade fleet over the columnar wire: healthy baseline, then a
    thermal-throttle root in group 0 that cascades into group 1.  With
    ``session=True`` uploads ride one persistent WireEncoder session
    (v3 dictionary-delta frames) instead of stateless frames."""
    cl = sc.cascade_fleet(layout, links=((0, 1),), seed=seed,
                          columnar=True, samples_per_iter=samples)
    for slo in sc.fleet_slos(cl, margin=0.05):
        svc.register_slo(slo)
    enc = WireEncoder(cl.tables) if session else None

    def run(iterations):
        for _ in range(iterations):
            profiles = cl.step()
            batch = ColumnarBatch("job-0", profiles, "node-0", cl.tables)
            if enc is not None:
                svc.ingest_encoded(enc.encode(batch))
                enc.commit()
            else:
                svc.ingest_encoded(encode_batch(batch))
            if cl.iteration % 10 == 0:
                svc.process()
        svc.process()

    baseline, fault = iters
    run(baseline)
    cl.add_fleet_fault(sc.thermal_throttle(rank=fault_rank, start=cl.iteration,
                                           factor=1.5))
    run(fault)
    return cl


def _event_keys(svc):
    """Events minus the wall-clock stamps (detected_at and latency
    legitimately differ between service instances)."""
    out = []
    for e in svc.events:
        d = e.to_dict()
        d.pop("detected_at")
        d.pop("diagnosis_latency_s")
        out.append(d)
    return out


def _finding_key(f):
    return (f.breach.slo, f.breach.metric, f.breach.group_id,
            f.breach.rank, f.breach.value, f.breach.threshold,
            f.breach.window, f.breach.epoch, f.root_group, f.root_rank,
            f.root_node, f.root_cause, f.category, f.epoch,
            tuple(f.evidence["chain"]))


@pytest.fixture(scope="module")
def driven():
    sharded = ShardedService(n_shards=4)
    _drive(sharded)
    pod = PodTierService(n_pods=4, pods_per_shard=2)
    _drive(pod, session=True)
    return sharded, pod


# ---------------------------------------------------------------------------
# digest merge semantics
# ---------------------------------------------------------------------------

def _digest(pod, alerts, summaries, sids, weights):
    return PodDigest(pod=pod, alerts=list(alerts), summaries=dict(summaries),
                     groups=len(summaries), ranks=8,
                     flame_sids=np.asarray(sids, dtype=np.int64),
                     flame_weights=np.asarray(weights, dtype=np.float64))


def test_merge_digests_preserves_pod_order():
    a = _digest(0, ["a0", "a1"], {"g0": "b0"}, [1, 3], [2.0, 1.0])
    b = _digest(1, ["b0"], {"g1": "b1", "g0": "b0'"}, [3, 5], [1.0, 4.0])
    m = merge_digests([a, b])
    assert m.pod == -1
    # alerts concatenate in input order — the facade sorts once, at the top
    assert m.alerts == ["a0", "a1", "b0"]
    # summaries merge in input order (later pods win shared keys, same as
    # the flat facade's dict.update walk)
    assert m.summaries == {"g0": "b0'", "g1": "b1"}
    assert m.groups == 3 and m.ranks == 16
    # flame columns: deduplicated union with summed weights
    assert m.flame_sids.tolist() == [1, 3, 5]
    assert m.flame_weights.tolist() == [2.0, 2.0, 4.0]
    assert m.flame_total == pytest.approx(8.0)


def test_merge_digests_empty_and_nested():
    empty = merge_digests([])
    assert empty.alerts == [] and empty.flame_sids.shape == (0,)
    assert empty.flame_total == 0.0
    a = _digest(0, ["x"], {}, [7], [1.5])
    # merging a merge (the two-level tree) flattens losslessly
    two_level = merge_digests([merge_digests([a]), empty])
    flat = merge_digests([a])
    assert two_level.alerts == flat.alerts
    assert two_level.flame_sids.tolist() == flat.flame_sids.tolist()
    assert two_level.flame_weights.tolist() == flat.flame_weights.tolist()


def test_pod_flame_columns_match_engine_graphs(driven):
    _sharded, pod = driven
    for agg in pod.pods:
        sids, weights = agg.flame_columns()
        want = merge_stack_columns(
            [(fg._vec.nonzero()[0], fg._vec[fg._vec.nonzero()[0]])
             for fg in agg.engine._rank_fg.values()
             if getattr(fg, "_vec", None) is not None])
        assert sids.tolist() == want[0].tolist()
        np.testing.assert_allclose(weights, want[1])


def test_pods_per_shard_validation():
    with pytest.raises(ValueError):
        PodTierService(n_pods=4, pods_per_shard=0)
    # oversized slice clamps to the pod count — one slice
    svc = PodTierService(n_pods=2, pods_per_shard=64)
    assert svc.pods_per_shard == 2 and len(svc.pod_slices) == 1


# ---------------------------------------------------------------------------
# facade equivalence: pod tier == flat sharded, events and audit()
# ---------------------------------------------------------------------------

def test_pod_tier_events_match_sharded(driven):
    sharded, pod = driven
    assert _event_keys(pod) == _event_keys(sharded)
    assert len(pod.events) > 0
    root_g = None
    for e in pod.events:
        if e.root_cause == "thermal_throttling_cpu" or e.straggler_rank == 2:
            root_g = e.group_id
            break
    assert root_g is not None


def test_pod_tier_snapshot_matches_sharded(driven):
    sharded, pod = driven
    ps, ss = pod.snapshot(), sharded.snapshot()
    assert ps.epoch == ss.epoch
    assert ps.group_ids() == ss.group_ids()
    for g in ps.group_ids():
        pv, sv = ps.group(g), ss.group(g)
        assert pv.ranks == sv.ranks
        assert pv.last_iteration == sv.last_iteration
        assert pv.waterline_top == sv.waterline_top
        assert pv.blame == sv.blame
    assert ps.blame_roots == ss.blame_roots


def test_audit_identical_with_and_without_pod_tier(driven):
    sharded, pod = driven
    fp = sorted(map(_finding_key, pod.audit()))
    fs = sorted(map(_finding_key, sharded.audit()))
    assert fp == fs and len(fp) > 0


def test_pod_parallel_matches_serial():
    serial = PodTierService(n_pods=4, pods_per_shard=2, parallel=False)
    _drive(serial, iters=(20, 20))
    par = PodTierService(n_pods=4, pods_per_shard=2, parallel=True)
    _drive(par, iters=(20, 20))
    assert _event_keys(par) == _event_keys(serial)
    assert sorted(map(_finding_key, par.audit())) \
        == sorted(map(_finding_key, serial.audit()))


def test_pod_stats_expose_tier_shape(driven):
    _sharded, pod = driven
    stats = pod.stats()
    assert stats["pods"] == 4
    assert stats["pod_slices"] == 2
    # 15 physical ranks, but bridge rank 7 lives in both groups and its
    # groups route to different pods — each pod counts its own copy
    assert stats["digest_ranks"] == 16
    assert stats["digest_stacks"] > 0


@pytest.mark.slow
def test_pod_tier_equivalence_mid_scale():
    """64 groups x 8 ranks (~512 ranks): the pod path and the flat
    sharded path still agree event-for-event and audit-for-audit."""
    layout = [list(range(8 * i, 8 * (i + 1))) for i in range(64)]
    layout[1][0] = 7                        # bridge rank chains g0 -> g1
    sharded = ShardedService(n_shards=8)
    _drive(sharded, layout=layout, samples=40, iters=(12, 12))
    pod = PodTierService(n_pods=8, pods_per_shard=4, parallel=True)
    _drive(pod, session=True, layout=layout, samples=40, iters=(12, 12))
    assert _event_keys(pod) == _event_keys(sharded)
    assert sorted(map(_finding_key, pod.audit())) \
        == sorted(map(_finding_key, sharded.audit()))
