"""Streaming-service invariants: bounded state, incremental flame graphs,
ring-buffered windows, and agreement with the legacy batch path."""
import pytest

from repro.core import simcluster as sc
from repro.core.baseline import BaselineStore
from repro.core.flamegraph import FlameGraph
from repro.core.service import CentralService
from repro.core.sharded import ShardedService, shard_of


# -- FlameGraph streaming primitives ----------------------------------------

def test_add_graph_matches_merge():
    a, b = FlameGraph(), FlameGraph()
    a.add(("main", "f"), 3)
    b.add(("main", "g"), 2)
    b.add(("main", "f"), 1)
    merged = a.merge(b)
    a.add_graph(b)
    assert a.counts == merged.counts
    assert a.total == merged.total


def test_decay_preserves_fractions_and_prunes():
    fg = FlameGraph()
    fg.add(("main", "hot"), 80)
    fg.add(("main", "cold"), 20)
    before = fg.function_fractions()
    fg.decay(0.5)
    after = fg.function_fractions()
    for fn, fr in before.items():
        assert after[fn] == pytest.approx(fr)
    # tiny stacks are dropped once decayed under the prune floor
    fg2 = FlameGraph()
    fg2.add(("x",), 1)
    for _ in range(20):
        fg2.decay(0.5)
    assert fg2.counts == {}
    assert fg2.total == 0


def test_copy_is_independent():
    fg = FlameGraph()
    fg.add(("a",), 5)
    snap = fg.copy()
    fg.add(("a",), 5)
    fg.decay(0.1)
    assert snap.counts[("a",)] == 5
    assert snap.total == 5


# -- bounded service state ---------------------------------------------------

def test_streaming_state_is_bounded():
    svc = CentralService(window=50)
    cl = sc.SimCluster(n_ranks=8, seed=0, samples_per_iter=100)
    cl.run(svc, 300, process_every=10)
    st = svc.stats()
    assert st["ingested"] == 300 * 8
    assert st["iter_time_entries"] <= 50           # ring buffer, not history
    assert st["ranks"] == 8
    # decayed per-rank graphs hold the *live* stack set, not one entry per
    # ever-observed sample: total weight ~ samples_per_iter * fg_window
    for fg in svc._rank_fg.values():
        assert fg.total < 100 * svc.fg_window * 2
        assert len(fg.counts) < 64


def test_legacy_mode_keeps_full_history():
    svc = CentralService(window=50, streaming=False)
    cl = sc.SimCluster(n_ranks=4, seed=0, samples_per_iter=50)
    cl.run(svc, 120, process_every=40)
    # grow-forever list: one entry per ingested profile (4 ranks x 120)
    assert svc.stats()["iter_time_entries"] == 120 * 4


@pytest.mark.parametrize("fault,robust", [
    (sc.thermal_throttle(0, start=30), False),
    (sc.nic_softirq(4, start=30), False),
    (sc.logging_overhead(start=30), False),
])
def test_streaming_matches_legacy_diagnoses(fault, robust):
    import copy
    results = []
    for streaming in (True, False):
        svc = CentralService(window=50, robust_detector=robust,
                             streaming=streaming)
        cl = sc.SimCluster(n_ranks=8, seed=7)
        cl.run(svc, 30)
        cl.add_fault(copy.deepcopy(fault))
        cl.run(svc, 60)
        results.append([(e.root_cause, e.category, e.straggler_rank)
                        for e in svc.events])
    assert results[0] and results[0][0] == results[1][0]


def test_event_counts_incremental():
    svc = CentralService(window=50)
    cl = sc.SimCluster(n_ranks=8, seed=7)
    cl.run(svc, 30)
    cl.add_fault(sc.nic_softirq(4, start=30))
    cl.run(svc, 60)
    counts = svc.event_counts()
    assert counts.get("os_interference", 0) == sum(
        1 for e in svc.events if e.category == "os_interference")
    svc.ingest_log_line("job-0", "worker: CUDA out of memory at step 12")
    assert svc.event_counts()["software"] >= 1


def test_idle_groups_are_evicted():
    import time as _time
    svc = CentralService(window=50, group_ttl_s=100.0)
    cl = sc.SimCluster(n_ranks=4, seed=0, samples_per_iter=50)
    cl.run(svc, 20, process_every=10)
    g = cl.group_id
    assert g in svc._group_ranks
    svc._last_ingest[g] = _time.monotonic() - 101.0   # simulate idleness
    svc.process()
    assert svc.groups_evicted == 1
    assert g not in svc._group_ranks
    assert g not in svc.waterlines
    assert g not in svc._group_iter_time
    assert not any(gg == g for (gg, _r) in svc._rank_fg)
    assert not any(gg == g for (gg, _r) in svc._latest)
    assert g not in svc.detector._groups
    assert g not in svc.detector.aligner._groups
    # a re-appearing group starts clean and is analysed normally again
    cl.run(svc, 20, process_every=10)
    assert g in svc._group_ranks


# -- baseline store bounds ---------------------------------------------------

def test_baseline_store_lru_bound():
    store = BaselineStore(max_entries=3)
    fg = FlameGraph()
    fg.add(("m",), 1)
    for i in range(5):
        store.save("job", f"g{i}", fg, iter_time=0.1)
    assert len(store) == 3
    assert store.evicted == 2
    assert store.get("job", "g0") is None
    assert store.get("job", "g4") is not None
    assert store.iter_time("job", "g0") is None


def test_baseline_iter_time_reads_keep_entry_warm():
    """_check_temporal only touches a healthy group's baseline via
    iter_time(); that read must refresh LRU position or churn from other
    jobs evicts an actively-monitored baseline."""
    store = BaselineStore(max_entries=2)
    fg = FlameGraph()
    fg.add(("m",), 1)
    store.save("job", "live", fg, iter_time=0.1)
    store.save("job", "other0", fg, iter_time=0.1)
    assert store.iter_time("job", "live") == 0.1      # warm the live entry
    store.save("job", "other1", fg, iter_time=0.1)    # evicts other0
    assert store.get("job", "live") is not None
    assert store.get("job", "other0") is None


def test_baseline_store_snapshots_live_graphs():
    store = BaselineStore()
    fg = FlameGraph()
    fg.add(("m",), 10)
    store.save("job", "g", fg)
    fg.decay(0.01)                      # mutate the live graph afterwards
    saved = store.get("job", "g")
    assert saved.counts[("m",)] == 10


# -- sharded routing ---------------------------------------------------------

def test_shard_routing_is_stable_and_total():
    groups = [f"{h:016x}" for h in range(97)]
    for g in groups:
        idx = shard_of(g, 8)
        assert 0 <= idx < 8
        assert idx == shard_of(g, 8)    # deterministic


def test_sharded_service_routes_groups_to_distinct_shards():
    fleet = sc.MultiGroupSimCluster(n_groups=8, ranks_per_group=4, seed=1,
                                    samples_per_iter=40)
    svc = ShardedService(n_shards=4, window=50)
    fleet.run(svc, 12, process_every=6)
    assert svc.ingested == 8 * 4 * 12
    populated = [s for s in svc.shards if s.ingested]
    assert len(populated) >= 2          # groups actually spread out
    # each group's state lives on exactly its routed shard
    for g in fleet.group_ids():
        owner = svc.shard_for(g)
        for s in svc.shards:
            assert (g in s._group_ranks) == (s is owner)


def test_sharded_symbol_repo_is_shared():
    svc = ShardedService(n_shards=3)
    assert all(s.symbol_repo is svc.symbol_repo for s in svc.shards)


def test_sharded_log_lines_round_robin():
    svc = ShardedService(n_shards=2)
    for i in range(4):
        ev = svc.ingest_log_line("job-0", "NCCL timeout on rank 3")
        assert ev is not None and ev.root_cause == "nccl_timeout"
    assert svc.event_counts() == {"software": 4}
    assert all(len(s.events) == 2 for s in svc.shards)
