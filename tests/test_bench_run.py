"""benchmarks/run.py harness contract: a raising bench module must exit
non-zero and must mark the failure inside the emitted JSON, so CI can
never upload a partial trajectory as green."""
import json
import sys
import types
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import benchmarks.run as runmod  # noqa: E402


def _module(name: str, run):
    mod = types.ModuleType(name)
    mod.run = run
    return mod


def _patch(monkeypatch, tmp_path, modules):
    names = []
    for name, fn in modules:
        full = f"benchmarks.{name}"
        monkeypatch.setitem(sys.modules, full, _module(full, fn))
        names.append(full)
    monkeypatch.setattr(runmod, "MODULES", names)
    monkeypatch.setattr(runmod, "JSON_PATH", str(tmp_path / "bench.json"))
    monkeypatch.setattr(sys, "argv", ["run.py"])
    return tmp_path / "bench.json"


def test_run_exits_nonzero_when_a_module_raises(monkeypatch, tmp_path):
    def ok(lines):
        lines.append("ok_metric,2,fine")

    def boom(lines):
        lines.append("partial_metric,1,emitted-before-crash")
        raise RuntimeError("kaboom")

    json_path = _patch(monkeypatch, tmp_path,
                       [("_ok", ok), ("_boom", boom)])
    with pytest.raises(SystemExit) as exc:
        runmod.main()
    assert exc.value.code == 1
    data = json.loads(json_path.read_text())
    # the partial JSON is still written (the trajectory survives) ...
    assert data["ok_metric"]["derived"] == "fine"
    assert data["partial_metric"]["derived"] == "emitted-before-crash"
    # ... but it is self-describing about the failure
    assert data["_boom_wall"]["derived"].startswith("FAILED")
    assert data["bench_run_failures"]["count"] == 1
    assert "_boom" in data["bench_run_failures"]["derived"]


def test_run_exits_zero_and_marks_no_failures_when_green(monkeypatch,
                                                         tmp_path):
    def ok(lines):
        lines.append("ok_metric,2,fine")

    json_path = _patch(monkeypatch, tmp_path, [("_ok", ok)])
    runmod.main()                       # no SystemExit
    data = json.loads(json_path.read_text())
    assert data["bench_run_failures"]["count"] == 0
    assert data["ok_metric"]["us_per_call"] == 2.0


def test_run_rejects_unknown_selection(monkeypatch, tmp_path):
    _patch(monkeypatch, tmp_path, [("_ok", lambda lines: None)])
    monkeypatch.setattr(sys, "argv", ["run.py", "no_such_bench"])
    with pytest.raises(SystemExit) as exc:
        runmod.main()
    assert exc.value.code == 2
