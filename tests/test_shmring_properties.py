"""Property tests for the SPSC shared-memory ring: arbitrary
record-size schedules round-trip in order across wrap boundaries, the
reader never observes bytes that were not committed, and the
overflow→pipe-fallback policy preserves end-to-end payload ordering
(the invariant the transport layer's ring-first upload path relies
on)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.shmring import ShmRing  # noqa: E402

# small capacity so generated schedules cross the wrap marker often
_CAP = 1 << 12
# payload sizes around the interesting edges: empty, sub-alignment,
# alignment multiples, and near-capacity
_sizes = st.one_of(
    st.integers(min_value=0, max_value=64),
    st.integers(min_value=_CAP // 4, max_value=_CAP - 16))
# a schedule interleaves produce (a size) and consume (None) steps
_schedules = st.lists(st.one_of(_sizes, st.none()), max_size=200)


def _payload(i: int, size: int) -> bytes:
    return bytes([(i * 31 + j) % 251 for j in range(size)])


@settings(deadline=None, max_examples=60)
@given(_schedules)
def test_roundtrip_in_order_across_wraps(schedule):
    ring = ShmRing(_CAP)
    pending = []
    produced = 0
    for step in schedule:
        if step is None:
            got = ring.pop()
            if got is None:
                assert not pending
            else:
                seq, view = got
                want_seq, want = pending.pop(0)
                assert seq == want_seq
                assert bytes(view) == want
                ring.release()
        else:
            seq = ring.push(_payload(produced, step))
            if seq is not None:
                assert seq == produced
                pending.append((seq, _payload(produced, step)))
                produced += 1
    for want_seq, want in pending:
        seq, view = ring.pop()
        assert (seq, bytes(view)) == (want_seq, want)
        ring.release()
    assert ring.pop() is None


@settings(deadline=None, max_examples=60)
@given(st.lists(st.tuples(_sizes, st.booleans()), max_size=60))
def test_reader_never_observes_uncommitted(steps):
    """Reserve-then-maybe-commit: whatever the commit/abandon pattern,
    every popped record is exactly a committed payload — never bytes
    from an abandoned (or still-pending) reservation."""
    ring = ShmRing(_CAP)
    committed = []
    produced = 0
    for size, do_commit in steps:
        mv = ring.reserve_max()
        if mv is None or len(mv) < size:
            if mv is not None:
                ring.cancel()
            # full: drain everything and verify against committed only
            while True:
                got = ring.pop()
                if got is None:
                    break
                assert bytes(got[1]) == committed.pop(0)
                ring.release()
            continue
        mv[:size] = _payload(produced, size)
        if do_commit:
            ring.commit(size)
            committed.append(_payload(produced, size))
            produced += 1
        else:
            ring.cancel()
    while True:
        got = ring.pop()
        if got is None:
            break
        assert bytes(got[1]) == committed.pop(0)
        ring.release()
    assert not committed


@settings(deadline=None, max_examples=60)
@given(st.lists(_sizes, max_size=80), st.integers(2, 6))
def test_overflow_fallback_preserves_ordering(sizes, drain_every):
    """Model the transport's ring-first upload: each payload goes to
    the ring, or — on overflow — to the pipe, and every send appends an
    announcement to the (FIFO) pipe.  Replaying announcements in pipe
    order must reproduce the exact send order, whichever path each
    payload took."""
    ring = ShmRing(_CAP)
    announcements = []          # ("ring", seq) | ("pipe", bytes)
    consumed = []

    def drain(upto=None):
        while announcements:
            kind, val = announcements.pop(0)
            if kind == "pipe":
                consumed.append(val)
            else:
                seq, view = ring.pop()
                assert seq == val
                consumed.append(bytes(view))
                ring.release()
            if upto is not None and len(consumed) >= upto:
                break

    sent = []
    for i, size in enumerate(sizes):
        p = _payload(i, size)
        seq = ring.push(p)
        announcements.append(("ring", seq) if seq is not None
                             else ("pipe", p))
        sent.append(p)
        if i % drain_every == 0:
            drain()
    drain()
    assert consumed == sent
    assert ring.pop() is None
