"""HLO collective parsing + dry-run bookkeeping units (the 512-device
dry-run itself runs via ``python -m repro.launch.dryrun``; here we test the
machinery on this process's single device)."""
import json
from pathlib import Path

import pytest

from repro.roofline.hlo import collective_bytes, shape_bytes

HLO = """
HloModule jit_step

ENTRY main {
  %p0 = bf16[256,4096,896]{2,1,0} parameter(0)
  %p1 = f32[1024,512]{1,0} parameter(1)
  %ag = bf16[256,4096,896]{2,1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[1024,512]{1,0} all-reduce(%p1), to_apply=%add
  %rs = f32[64,512]{1,0} reduce-scatter(%p1), dimensions={0}
  %cp = bf16[256,4096,896]{2,1,0} collective-permute(%ag), source_target_pairs={{0,1}}
  ROOT %t = (bf16[256,4096,896]{2,1,0}) tuple(%cp)
}
"""


def test_collective_bytes_from_hlo():
    total, by_op, counts = collective_bytes(HLO)
    p0 = 256 * 4096 * 896 * 2
    p1 = 1024 * 512 * 4
    assert by_op["all-gather"] == p0
    assert by_op["all-reduce"] == p1
    assert by_op["reduce-scatter"] == p1
    assert by_op["collective-permute"] == p0
    assert counts == {"all-gather": 1, "all-reduce": 1,
                      "reduce-scatter": 1, "collective-permute": 1}
    assert total == 2 * p0 + 2 * p1


def test_async_start_done_counted_once():
    hlo = """
  %p0 = f32[128]{0} parameter(0)
  %ags = f32[128]{0} all-gather-start(%p0), dimensions={0}
  %agd = f32[128]{0} all-gather-done(%ags)
"""
    total, by_op, counts = collective_bytes(hlo)
    assert counts["all-gather"] == 1
    assert by_op["all-gather"] == 128 * 4


def test_tuple_type_bytes():
    assert shape_bytes("(f32[8,8], bf16[4])") == 8 * 8 * 4 + 4 * 2


def test_dryrun_results_complete_if_present():
    """When the sweep has run, assert all 33 applicable cells passed on
    BOTH meshes (the multi-pod requirement)."""
    results = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not results.exists():
        pytest.skip("dry-run sweep not executed in this environment")
    recs = [json.loads(p.read_text()) for p in results.glob("*_baseline.json")]
    for pod in ("pod1", "pod2"):
        got = {(r["arch"], r["shape"]) for r in recs
               if r.get("ok") and (f"_{pod}_" in json.dumps(r) or
                                   r.get("multi_pod") == (pod == "pod2"))}
        assert len([r for r in recs
                    if r.get("ok") and r.get("multi_pod") == (pod == "pod2")]) >= 33, pod


def test_variants_registry():
    from repro.launch.dryrun import VARIANTS
    assert "baseline" in VARIANTS
    assert {"no_fsdp", "remat_none", "no_kvshard"} <= set(VARIANTS)
