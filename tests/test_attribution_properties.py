"""Hypothesis properties of the attribution layer.

Invariants (ISSUE acceptance):
  * per-iteration blame components sum to ``iter_time`` within tolerance
  * blame totals are preserved under rank relabeling
  * timelines/edges are invariant under profile ingestion order
  * the vectorized column pass equals the naive per-event Python walk
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.attribution import (iteration_timelines,  # noqa: E402
                                    iteration_timelines_naive)
from repro.core.events import (CollectiveEvent, IterationProfile,  # noqa: E402
                               KernelEvent, StackSample)
from repro.core.trace import profile_to_columnar, TraceTables  # noqa: E402

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

_FRAMES = st.sampled_from([
    ("py::train", "py::forward"),
    ("py::train", "ncclAllReduce"),
    ("py::train", "py::data_next", "read"),
    ("do_softirq", "net_rx_action"),
])


@st.composite
def _group_iteration(draw):
    """One synchronized iteration of a 2..6-rank group: per-rank entry
    delays, kernels and stacks, one or two collective ops."""
    n = draw(st.integers(2, 6))
    n_ops = draw(st.integers(1, 2))
    iter_time = draw(st.floats(0.05, 0.5))
    profiles = []
    for r in range(n):
        colls = []
        for op_i in range(n_ops):
            base = 0.02 + 0.05 * op_i
            entry = base + draw(st.floats(0.0, 0.01))
            dur = draw(st.floats(0.001, 0.02))
            colls.append(CollectiveEvent(
                rank=r, group_id="g", op=f"op{op_i}", entry=entry,
                exit=entry + dur))
        kernels = [
            KernelEvent(rank=r, name=f"k{i}",
                        start=draw(st.floats(0.0, 0.1)),
                        duration=draw(st.floats(0.0, 0.02)))
            for i in range(draw(st.integers(0, 3)))]
        stacks = [
            StackSample(rank=r, timestamp=0.0, frames=draw(_FRAMES),
                        weight=draw(st.integers(1, 20)))
            for _ in range(draw(st.integers(0, 4)))]
        profiles.append(IterationProfile(
            rank=r, iteration=0, group_id="g", iter_time=iter_time,
            cpu_samples=stacks, kernel_events=kernels, collectives=colls))
    return profiles


def _columnar(profiles, tables=None):
    t = tables if tables is not None else TraceTables()
    return [profile_to_columnar(p, t) for p in profiles]


@given(_group_iteration())
def test_components_sum_to_iter_time(profiles):
    tls, _ = iteration_timelines(_columnar(profiles))
    for tl in tls:
        assert tl.total == pytest.approx(tl.iter_time, abs=1e-9)
        assert all(c >= -1e-12 for c in tl.components())


@given(_group_iteration())
def test_vectorized_equals_naive(profiles):
    tls, edges = iteration_timelines(_columnar(profiles))
    tls_n, edges_n = iteration_timelines_naive(profiles)
    for a, b in zip(tls, tls_n):
        assert a.rank == b.rank
        assert a.components() == pytest.approx(b.components(), abs=1e-9)
    assert [(e.culprit_rank, e.victim_rank) for e in edges] == \
        [(e.culprit_rank, e.victim_rank) for e in edges_n]
    for x, y in zip(edges, edges_n):
        assert x.wait == pytest.approx(y.wait, abs=1e-12)


@given(_group_iteration(), st.randoms(use_true_random=False))
def test_blame_total_invariant_under_rank_relabeling(profiles, rnd):
    """Relabeling ranks permutes who is blamed, but never how much
    blame exists: total wait, per-timeline components and the edge
    multiset all map through the permutation."""
    ranks = [p.rank for p in profiles]
    new_ids = list(range(100, 100 + len(ranks)))
    rnd.shuffle(new_ids)
    mapping = dict(zip(ranks, new_ids))

    def relabel(p):
        return IterationProfile(
            rank=mapping[p.rank], iteration=p.iteration, group_id=p.group_id,
            iter_time=p.iter_time, cpu_samples=p.cpu_samples,
            kernel_events=p.kernel_events,
            collectives=[CollectiveEvent(
                rank=mapping[c.rank], group_id=c.group_id, op=c.op,
                entry=c.entry, exit=c.exit) for c in p.collectives])

    tls, edges = iteration_timelines(_columnar(profiles))
    tls_r, edges_r = iteration_timelines(_columnar(
        [relabel(p) for p in profiles]))
    assert sum(e.wait for e in edges) == pytest.approx(
        sum(e.wait for e in edges_r), abs=1e-9)
    by_rank = {tl.rank: tl for tl in tls}
    for tl in tls_r:
        orig = by_rank[{v: k for k, v in mapping.items()}[tl.rank]]
        assert tl.components() == pytest.approx(orig.components(), abs=1e-9)
    # edges map through the permutation (as a multiset; culprit ties may
    # break differently because ties break by rank id)
    waits = sorted(round(e.wait, 12) for e in edges)
    waits_r = sorted(round(e.wait, 12) for e in edges_r)
    assert waits == waits_r


@given(_group_iteration(), st.randoms(use_true_random=False))
def test_invariant_under_ingestion_order(profiles, rnd):
    tables = TraceTables()
    cols = _columnar(profiles, tables)
    shuffled = list(cols)
    rnd.shuffle(shuffled)
    tls, edges = iteration_timelines(cols)
    tls_s, edges_s = iteration_timelines(shuffled)
    a = {tl.rank: tl.components() for tl in tls}
    b = {tl.rank: tl.components() for tl in tls_s}
    assert set(a) == set(b)
    for r in a:
        assert a[r] == pytest.approx(b[r], abs=1e-9)
    assert sorted((e.culprit_rank, e.victim_rank, round(e.wait, 12))
                  for e in edges) == \
        sorted((e.culprit_rank, e.victim_rank, round(e.wait, 12))
               for e in edges_s)
