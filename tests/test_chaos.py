"""Chaos harness: seeded storms, fault teardown, flap damping, agent
dropout resync, simultaneous multi-root provenance, replay-scored
mitigation (ISSUE: verdict stability under fault storms)."""
import dataclasses

import pytest

from repro.core.chaos import (CHAOS_SCENARIO_POOL, ChaosEvent, ChaosRunner,
                              ChaosSchedule, TrueRoot, restart_perturbation)
from repro.core.diffdiag import VerdictDamper
from repro.core.service import CentralService
from repro.core.simcluster import (cascade_fleet, swap_thrash,
                                   thermal_throttle)
from repro.ft.mitigation import (MitigationAction, MitigationPlanner,
                                 MitigationReplayer)


def _two_group_layout():
    return [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]]


def _double_bridge_layout():
    """Five groups: two independent cascade domains (groups 0/1 bridge
    at global rank 7, groups 2/3 at rank 22) plus a disjoint always-
    healthy group on node 4 — the decoy target replay scoring must
    refuse to perturb."""
    layout = [[0, 1, 2, 3, 4, 5, 6, 7],
              [7] + list(range(8, 15)),
              list(range(15, 23)),
              [22] + list(range(23, 30)),
              list(range(32, 40))]
    return layout, [(0, 1), (2, 3)]


# ---------------------------------------------------------------------------
# satellite 1: fault teardown fully restores baseline effects
# ---------------------------------------------------------------------------


def test_remove_fault_mid_run_restores_baseline():
    """Inject two faults, run, clear them by name, then both the
    cleared fleet and a never-faulted twin must be event-free on fresh
    services AND back to baseline kernel/OS effects in the raw
    profiles.  (RNG streams diverge once a fault's os_effect consumes
    draws, so the contract is event-level + statistical equality, not
    byte equality.)"""
    layout = _two_group_layout()
    cleared = cascade_fleet(layout, [], seed=7)
    pristine = cascade_fleet(layout, [], seed=7)
    cleared.add_fault(0, swap_thrash(1, start=5))
    cleared.add_fault(1, thermal_throttle(9, start=5))
    for _ in range(25):
        cleared.step()
        pristine.step()
    assert cleared.remove_fault("memory_pressure_swap") == 1
    assert cleared.remove_fault("gpu_thermal_throttle", group_index=1) == 1
    assert all(not g.faults for g in cleared.groups)

    # event-equal: fresh services over the next N iterations see two
    # equally healthy fleets (the floor sits above cold-start jitter)
    ev_cleared = cleared.run(
        CentralService(window=20, min_root_lateness=5e-4), 30)
    ev_pristine = pristine.run(
        CentralService(window=20, min_root_lateness=5e-4), 30)
    assert ev_cleared == ev_pristine == []

    # and the raw effects are gone: no major-fault residue, iteration
    # times statistically at the never-faulted twin's level
    profs_c = cleared.step()
    profs_p = pristine.step()
    assert all(p.os_signals.major_faults < 1000 for p in profs_c)
    mean_c = sum(p.iter_time for p in profs_c) / len(profs_c)
    mean_p = sum(p.iter_time for p in profs_p) / len(profs_p)
    assert mean_c == pytest.approx(mean_p, rel=0.02)


def test_fault_end_iteration_expires():
    f = dataclasses.replace(swap_thrash(2, start=5), end_iteration=9)
    assert not f.applies(2, 4)          # not started
    assert f.applies(2, 5)
    assert f.applies(2, 8)
    assert not f.applies(2, 9)          # expired (end is exclusive)
    assert not f.applies(3, 6)          # wrong rank


# ---------------------------------------------------------------------------
# verdict flap damping (unit)
# ---------------------------------------------------------------------------


def test_verdict_damper_suppresses_single_cycle_flip():
    d = VerdictDamper(confirm=2, decay=0.5, retire_after=2)
    # first diagnosis emits immediately and stands
    assert d.propose("g", 1, "cause_a", 1.0) == {}
    d.tick()
    # a different cause on one cycle is suppressed, confidence decays
    assert d.propose("g", 1, "cause_b", 0.9) is None
    assert d.suppressed == 1
    st = d.standing("g", 1)
    assert st.cause == "cause_a"
    assert st.confidence == pytest.approx(0.5)
    assert st.pending_cause == "cause_b"
    d.tick()
    # the second consecutive cycle confirms the flip, with evidence
    info = d.propose("g", 1, "cause_b", 0.9)
    assert info["flap_damping"]["replaced"] == "cause_a"
    assert info["flap_damping"]["suppressed_cycles"] == 1
    assert d.flips_confirmed == 1
    assert d.standing("g", 1).cause == "cause_b"
    d.tick()                            # proposed this cycle: no decay
    d.tick()                            # absent 1: decay
    assert d.standing("g", 1).confidence == pytest.approx(0.45)
    d.tick()                            # absent 2: retire
    assert d.standing("g", 1) is None
    assert d.retired == 1
    # refresh semantics: same cause restores confidence, no flip
    d.propose("g2", 0, "x", 1.0)
    d.tick()
    d.tick()
    assert d.propose("g2", 0, "x", 0.8) == {}
    assert d.standing("g2", 0).confidence == pytest.approx(0.8)
    assert d.flips_confirmed == 1


def test_flapping_fault_does_not_flip_standing_verdict():
    """A hand-built flap (on at 20, off at 48, on again at 56): the OFF
    window covers exactly one analysis cycle, so its fallback proposal
    is a transient single-cycle anomaly — damped, the emitted stream
    never changes cause, and the root is still localized.  (A longer
    OFF window spanning ``confirm`` consecutive cycles WOULD flip,
    by design: sustained changes must get through.)"""
    layout = _two_group_layout()
    name = "chaos/gpu_thermal_throttle@g0r1"
    events = [
        ChaosEvent(iteration=20, kind="inject", name=name, group_index=0,
                   fault=dataclasses.replace(thermal_throttle(1, start=20),
                                             name=name)),
        ChaosEvent(iteration=48, kind="clear", name=name, group_index=0),
        ChaosEvent(iteration=56, kind="inject", name=name, group_index=0,
                   fault=dataclasses.replace(thermal_throttle(1, start=56),
                                             name=name)),
    ]
    roots = [TrueRoot(group_index=0, rank=1, cause="gpu_uniform_slowdown",
                      scenario="gpu_thermal_throttle",
                      category="gpu_hardware", flapping=True)]
    sched = ChaosSchedule(seed=13, layout=tuple(map(tuple, layout)),
                          links=(), horizon=100, events=events,
                          true_roots=roots)
    rep = ChaosRunner(sched, "streaming").run()
    assert rep.all_roots_localized, rep.missed_roots()
    assert rep.flips == 0, rep.event_tuples
    assert rep.service.stats()["verdicts_suppressed"] >= 1
    causes = {e.root_cause for e in rep.events
              if e.group_id == rep.cluster.group_ids()[0]}
    assert causes == {"gpu_uniform_slowdown"}


def test_standing_verdicts_exposed_by_services():
    layout = _two_group_layout()
    sched = ChaosSchedule.generate(2, layout, n_faults=1, horizon=80,
                                   flap_prob=1.0, n_dropouts=0,
                                   n_mitigation_blips=0)
    rep = ChaosRunner(sched, "sharded").run()
    standing = rep.service.standing_verdicts()
    root = sched.true_roots[0]
    gid = rep.cluster.group_ids()[root.group_index]
    assert any(k[0] == gid for k in standing), standing


# ---------------------------------------------------------------------------
# satellite 2: agent dropout -> resync -> backfill
# ---------------------------------------------------------------------------


def test_agent_dropout_resync_and_backfill():
    """One NodeAgent goes silent for 10 iterations while its rank keeps
    training, and the service loses its wire sessions mid-run.  No
    WireFormatError escapes flush (agents resync), the silent rank
    draws no straggler verdict, and its buffered profiles backfill the
    query snapshot's history on resume."""
    from repro.core.agent import AgentConfig, NodeAgent
    from repro.core.simcluster import SimCluster

    cl = SimCluster(n_ranks=4, seed=3, columnar=True)
    svc = CentralService(window=30, min_root_lateness=5e-4)
    a_main = NodeAgent(AgentConfig(node_id="node-0"), service=svc)
    a_r3 = NodeAgent(AgentConfig(node_id="node-1"), service=svc)
    silent = range(10, 20)
    for it in range(40):
        if it == 15:
            # the service loses every dictionary session: both agents'
            # next delta frame must trigger a resync, not an escape
            svc._wire_sessions.clear()
        for p in cl.step():
            (a_r3 if p.rank == 3 else a_main).submit(p)
        a_main.flush()
        if it not in silent:
            a_r3.flush()
        if cl.iteration % 10 == 0:
            svc.process()
    # retry the resynced frames until both agents have drained
    for _ in range(3):
        a_main.flush()
        a_r3.flush()
    svc.process()

    assert a_main.session_resyncs >= 1
    assert a_r3.session_resyncs >= 1
    assert a_main.upload_failures >= 1          # the lost-session flush
    assert not a_main._buffer and not a_r3._buffer
    assert all(e.straggler_rank != 3 for e in svc.events), [
        (e.root_cause, e.straggler_rank) for e in svc.events]
    hv = svc.snapshot().history[(cl.group_id, 3)]
    got = set(hv.it[:hv.n_it])
    assert set(silent) <= got, sorted(got)      # backfilled window
    assert got == set(range(40))                # nothing lost overall


def test_chaos_runner_holds_and_backfills_dropout_uploads():
    layout = _two_group_layout()
    sched = ChaosSchedule.generate(4, layout, n_faults=1, horizon=70,
                                   flap_prob=0.0, n_dropouts=1,
                                   n_mitigation_blips=0)
    dropped = sched.dropout_ranks()
    assert len(dropped) == 1
    rep = ChaosRunner(sched, "streaming").run()
    assert rep.all_roots_localized, rep.missed_roots()
    assert all(e.straggler_rank not in set(dropped) for e in rep.events)
    # the held ring drained: the dropout rank's history has no holes
    gi = next(i for i, g in enumerate(sched.layout) if dropped[0] in g)
    gid = rep.cluster.group_ids()[gi]
    hv = rep.service.snapshot().history[(gid, dropped[0])]
    assert set(hv.it[:hv.n_it]) == set(range(sched.horizon))


# ---------------------------------------------------------------------------
# satellite 3: two simultaneous roots in different groups
# ---------------------------------------------------------------------------


def _two_root_schedule():
    layout, links = _double_bridge_layout()
    ev = []
    for gi, fault, cause, scen, cat in [
            (0, swap_thrash(1, start=10), "memory_pressure_swap",
             "memory_pressure_swap", "os_interference"),
            (2, thermal_throttle(16, start=10), "gpu_uniform_slowdown",
             "gpu_thermal_throttle", "gpu_hardware")]:
        name = f"chaos/{scen}@g{gi}"
        ev.append(ChaosEvent(iteration=10, kind="inject", name=name,
                             group_index=gi,
                             fault=dataclasses.replace(fault, name=name)))
    roots = [TrueRoot(0, 1, "memory_pressure_swap", "memory_pressure_swap",
                      "os_interference", False),
             TrueRoot(2, 16, "gpu_uniform_slowdown", "gpu_thermal_throttle",
                      "gpu_hardware", False)]
    return ChaosSchedule(seed=21, layout=tuple(map(tuple, layout)),
                         links=tuple(map(tuple, links)), horizon=80,
                         events=ev, true_roots=roots)


def _empirical_slos(cluster, headroom: float = 7e-4, iters: int = 10):
    from repro.core.query import SLO
    pristine = cascade_fleet(
        [list(g) for g in (cluster.groups[i].rank_ids
                           for i in range(len(cluster.groups)))],
        list(cluster.cascade_links), seed=0)
    sums = {g.group_id: 0.0 for g in pristine.groups}
    for _ in range(iters):
        for p in pristine.step():
            sums[p.group_id] += p.iter_time
    out = []
    for g in pristine.groups:
        mean = sums[g.group_id] / (iters * g.n_ranks)
        out.append(SLO(name=f"iter-time/{g.group_id}", metric="iter_time",
                       threshold=mean + headroom, group_id=g.group_id,
                       window=8))
    return out


def test_two_simultaneous_roots_localized_with_provenance():
    """Two concurrent roots in different cascade domains: both
    localized, each victim group's export points at its own root,
    ``audit()`` walks every breach to the right (node, rank), and the
    planner never touches a victim node — identically on the central,
    sharded and pod-tier paths."""
    from repro.core.attribution import CASCADE_EXPORT_CAUSE

    reports = {p: ChaosRunner(_two_root_schedule(), p).run()
               for p in ("streaming", "sharded", "pod")}
    tuples = {p: r.event_tuples for p, r in reports.items()}
    assert tuples["streaming"] == tuples["sharded"] == tuples["pod"]

    for path, rep in reports.items():
        gids = rep.cluster.group_ids()
        assert rep.all_roots_localized, (path, rep.missed_roots())
        # victim-side provenance: g1 exports blame to g0, g3 to g2
        exports = {e.group_id: e.verdict.evidence.get("exported_to")
                   for e in rep.events
                   if e.root_cause == CASCADE_EXPORT_CAUSE}
        assert exports == {gids[1]: gids[0], gids[3]: gids[2]}, (path,
                                                                 exports)
        # time-travel audit: every SLO breach resolves to a true root.
        # Thresholds come from a pristine twin fleet (per-group healthy
        # iteration time + headroom above noise, below the faults'
        # ~1 ms lateness): the groups' staggered collective phases make
        # one nominal-base margin meaningless across the fleet.
        for slo in _empirical_slos(rep.cluster):
            rep.service.register_slo(slo)
        findings = rep.service.audit()
        assert findings, path
        assert ({(f.root_group, f.root_rank, f.root_node)
                 for f in findings}
                == {(gids[0], 1, 0), (gids[2], 16, 2)}), path
        # victim breaches arrive via a two-hop chain, roots via one-hop
        chains = {tuple(f.evidence["chain"]) for f in findings}
        assert (gids[1], gids[0]) in chains, (path, chains)
        assert (gids[3], gids[2]) in chains, (path, chains)
        # mitigation only ever touches the two culprit nodes
        planner = MitigationPlanner()
        for e in rep.events:
            planner.on_diagnosis(e)
        touched = {n for a in planner.actions
                   if a.kind in ("cordon", "restart_elastic")
                   for n in a.target_nodes}
        assert touched <= {0, 2}, (path, planner.actions)


# ---------------------------------------------------------------------------
# replay-scored mitigation
# ---------------------------------------------------------------------------


def test_replayer_approves_culprit_and_rejects_decoy():
    sched = _two_root_schedule()
    rep = ChaosRunner(sched, "streaming").run()
    replayer = MitigationReplayer(rep.cluster, margin=0.98)
    # cordoning the thermal culprit's node clears its fault and helps
    rv = replayer.score(MitigationAction(
        kind="cordon", target_nodes=[2], plan=None,
        reason="thermal culprit", source="diagnosis"))
    assert rv.approved, rv
    assert "chaos/gpu_thermal_throttle@g2" in rv.cleared_faults
    assert rv.trial_residual < rv.base_residual
    # cordoning the node of the always-healthy group is vetoed for
    # perturbing a group the do-nothing fork found healthy
    rv = replayer.score(MitigationAction(
        kind="cordon", target_nodes=[4], plan=None,
        reason="decoy", source="diagnosis"))
    assert not rv.approved
    assert rv.perturbed_healthy_groups
    # non-perturbing kinds pass through without a fork
    rv = replayer.score(MitigationAction(
        kind="observe", target_nodes=[], plan=None, reason="",
        source="diagnosis"))
    assert rv.approved and rv.reason.startswith("non-perturbing")
    assert len(replayer.scored) == 3


def test_planner_downgrades_replay_rejected_action():
    sched = _two_root_schedule()
    rep = ChaosRunner(sched, "streaming").run()

    class VetoAll(MitigationReplayer):
        def score(self, action):
            from repro.ft.mitigation import ReplayVerdict
            rv = ReplayVerdict(False, 1.0, 1.0, (), ("g",), "vetoed")
            self.scored.append(rv)
            return rv

    planner = MitigationPlanner(replayer=VetoAll(rep.cluster))
    for e in rep.events:
        planner.on_diagnosis(e)
    perturbing = [a for a in planner.actions
                  if a.kind in ("cordon", "restart_elastic")]
    assert not perturbing                        # all downgraded
    downgraded = [a for a in planner.actions
                  if a.kind == "observe" and a.replay is not None]
    assert downgraded and all(not a.replay.approved for a in downgraded)
    assert all("replay rejected" in a.reason for a in downgraded)


# ---------------------------------------------------------------------------
# schedule generation contracts
# ---------------------------------------------------------------------------


def test_generated_schedule_avoids_bridges_and_victim_groups():
    layout, links = _double_bridge_layout()
    sched = ChaosSchedule.generate(6, layout, links, n_faults=3,
                                   horizon=100, n_dropouts=1)
    bridges = {7, 22}
    storm_groups = {r.group_index for r in sched.true_roots}
    assert len(storm_groups) == 3               # one fault per group
    for r in sched.true_roots:
        assert r.rank not in bridges
        assert r.rank in layout[r.group_index]
        assert r.scenario in CHAOS_SCENARIO_POOL
    # dropouts come from storm-free groups and non-culprit ranks
    culprits = {r.rank for r in sched.true_roots}
    for dr in sched.dropout_ranks():
        assert dr not in culprits
        gi = next(i for i, g in enumerate(layout) if dr in g)
        assert gi not in storm_groups
    # flapping faults always end with a live burst (assertable roots)
    for r in sched.true_roots:
        if not r.flapping:
            continue
        name = f"chaos/{r.scenario}@g{r.group_index}r{r.rank}"
        last = max((e for e in sched.events if e.name == name),
                   key=lambda e: e.iteration)
        assert last.kind == "inject"


def test_restart_perturbation_window():
    f = restart_perturbation("x", [0, 1], start=10, duration=3,
                             severity=0.2)
    assert f.entry_delay(0.1) == pytest.approx(0.02)
    assert not f.applies(0, 9)
    assert f.applies(0, 10) and f.applies(1, 12)
    assert not f.applies(0, 13)


# ---------------------------------------------------------------------------
# satellite 6: the long storm stays out of tier-1
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_long_storm_1k_ranks():
    """>=1k ranks, >=200 iterations: six faults (some flapping), two
    dropouts, columnar path thinned via cluster_kwargs."""
    layout = [list(range(b, b + 8)) for b in range(0, 1024, 8)]
    sched = ChaosSchedule.generate(3, layout, [], n_faults=6,
                                   horizon=200, n_dropouts=2)
    rep = ChaosRunner(sched, "columnar", process_every=20,
                      cluster_kwargs={"samples_per_iter": 64}).run()
    assert rep.all_roots_localized, rep.missed_roots()
    assert rep.flip_rate <= 0.1, (rep.flips, len(rep.events))
    dropped = set(sched.dropout_ranks())
    assert all(e.straggler_rank not in dropped for e in rep.events)


# ---------------------------------------------------------------------------
# collection-plane faults: pod_kill / pod_slow storm events
# ---------------------------------------------------------------------------


def test_generated_pod_faults_paired_distinct_and_bounded():
    layout = _two_group_layout()
    sched = ChaosSchedule.generate(
        5, layout, n_faults=1, horizon=120, n_pod_faults=3, n_pods=4,
        pod_fault_at=(55, 70), pod_fault_len=(10, 18))
    pod_evs = [e for e in sched.events if e.pod is not None]
    kills = [e for e in pod_evs if e.kind in ("pod_kill", "pod_slow")]
    ups = [e for e in pod_evs if e.kind == "pod_up"]
    assert len(kills) == 3 and len(ups) == 3
    assert len({e.pod for e in kills}) == 3          # distinct pods
    assert all(0 <= e.pod < 4 for e in pod_evs)
    assert all(55 <= e.iteration <= 70 for e in kills)
    by_pod = {e.pod: e.iteration for e in kills}
    assert all(10 <= u.iteration - by_pod[u.pod] <= 18 for u in ups)
    # the storm replays bit-identically from the seed, pod faults and all
    replay = ChaosSchedule.generate(
        5, layout, n_faults=1, horizon=120, n_pod_faults=3, n_pods=4,
        pod_fault_at=(55, 70), pod_fault_len=(10, 18))
    key = [(e.iteration, e.kind, e.name, e.group_index, e.rank, e.pod)
           for e in sched.events]
    assert [(e.iteration, e.kind, e.name, e.group_index, e.rank, e.pod)
            for e in replay.events] == key


def test_generated_pod_faults_require_enough_pods():
    layout = _two_group_layout()
    with pytest.raises(ValueError, match="n_pod_faults"):
        ChaosSchedule.generate(5, layout, n_faults=1, horizon=120,
                               n_pod_faults=5, n_pods=4)


def test_pod_fault_events_are_noops_on_flat_paths():
    """A storm with collection-plane faults still replays on service
    paths without a pod tier — the pod events simply do not apply."""
    layout, links = _two_group_layout(), ()
    sched = ChaosSchedule.generate(7, layout, links, n_faults=1,
                                   horizon=60, n_pod_faults=2, n_pods=4,
                                   pod_fault_at=(30, 40),
                                   pod_fault_len=(5, 8))
    rep = ChaosRunner(sched, "sharded").run()
    assert rep.all_roots_localized, rep.missed_roots()


def test_runner_rejects_unknown_path_but_accepts_podproc():
    layout, links = _two_group_layout(), ()
    sched = ChaosSchedule.generate(7, layout, links, n_faults=1,
                                   horizon=40)
    with pytest.raises(ValueError, match="unknown service path"):
        ChaosRunner(sched, "quantum")
    runner = ChaosRunner(sched, "podproc", n_shards=2)
    try:
        assert type(runner.service).__name__ == "MultiProcPodService"
    finally:
        runner.close()
