"""In-kernel-analog aggregation (§4) + Python/native stack stitching."""
import sys

from repro.core.aggregate import StackAggregator
from repro.core.events import RawStackSample
from repro.core.stitch import NativeFrame, PyFrame, stitch, walk_pyframes


def _sample(frames, rank=0, w=1):
    return RawStackSample(rank=rank, timestamp=0.0, frames=tuple(frames),
                          weight=w)


def test_aggregation_reduction_factor():
    """The paper's 10-50x: many samples, few unique stacks."""
    agg = StackAggregator()
    stacks = [tuple((f"bid{i}", j) for j in range(20)) for i in range(10)]
    for n in range(2000):
        agg.record(_sample(stacks[n % len(stacks)]))
    out = agg.drain()
    assert len(out) == 10
    assert sum(c for _, c in out) == 2000        # conservation
    assert 10 <= agg.stats.reduction <= 500
    assert agg.stats.reduction >= 50             # this workload: 200x-ish


def test_aggregation_overflow_passthrough():
    agg = StackAggregator(max_entries=4)
    for i in range(10):
        agg.record(_sample([(f"b{i}", 0)]))
    out = agg.drain()
    assert sum(c for _, c in out) == 10          # nothing lost


def test_drain_resets():
    agg = StackAggregator()
    agg.record(_sample([("b", 1)]))
    assert len(agg.drain()) == 1
    assert agg.drain() == []


# -- stitching ----------------------------------------------------------------

def test_stitch_replaces_evaluator_frames():
    native = [  # leaf..root
        NativeFrame("memcpy", sp=100),
        NativeFrame("at::native::softmax", sp=200),
        NativeFrame("_PyEval_EvalFrameDefault", sp=300),
        NativeFrame("_PyEval_EvalFrameDefault", sp=500),
        NativeFrame("Py_RunMain", sp=700),
    ]
    python = [  # leaf..root
        PyFrame("forward", "model.py", 10, native_sp=290),
        PyFrame("train_step", "loop.py", 55, native_sp=480),
    ]
    merged = stitch(native, python)
    assert merged == ("Py_RunMain", "py::train_step", "py::forward",
                      "at::native::softmax", "memcpy")


def test_stitch_pure_native_passthrough():
    native = [NativeFrame("a", 1), NativeFrame("b", 2)]
    assert stitch(native, []) == ("b", "a")


def test_walk_real_python_frames():
    def inner():
        return walk_pyframes(sys._getframe())

    def outer():
        return inner()

    frames = outer()
    names = [f.code_name for f in frames]
    assert names[0] == "inner" and "outer" in names
    labels = [f.label for f in frames]
    assert labels[0] == "py::inner"
