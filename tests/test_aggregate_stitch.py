"""In-kernel-analog aggregation (§4) + Python/native stack stitching."""
import sys

from repro.core.aggregate import StackAggregator
from repro.core.events import RawStackSample
from repro.core.stitch import NativeFrame, PyFrame, stitch, walk_pyframes


def _sample(frames, rank=0, w=1):
    return RawStackSample(rank=rank, timestamp=0.0, frames=tuple(frames),
                          weight=w)


def test_aggregation_reduction_factor():
    """The paper's 10-50x: many samples, few unique stacks."""
    agg = StackAggregator()
    stacks = [tuple((f"bid{i}", j) for j in range(20)) for i in range(10)]
    for n in range(2000):
        agg.record(_sample(stacks[n % len(stacks)]))
    out = agg.drain()
    assert len(out) == 10
    assert sum(c for _, c in out) == 2000        # conservation
    assert 10 <= agg.stats.reduction <= 500
    assert agg.stats.reduction >= 50             # this workload: 200x-ish


def test_aggregation_overflow_passthrough():
    agg = StackAggregator(max_entries=4)
    for i in range(10):
        agg.record(_sample([(f"b{i}", 0)]))
    out = agg.drain()
    assert sum(c for _, c in out) == 10          # nothing lost


def test_drain_resets():
    agg = StackAggregator()
    agg.record(_sample([("b", 1)]))
    assert len(agg.drain()) == 1
    assert agg.drain() == []


# -- stitching ----------------------------------------------------------------

def test_stitch_replaces_evaluator_frames():
    native = [  # leaf..root
        NativeFrame("memcpy", sp=100),
        NativeFrame("at::native::softmax", sp=200),
        NativeFrame("_PyEval_EvalFrameDefault", sp=300),
        NativeFrame("_PyEval_EvalFrameDefault", sp=500),
        NativeFrame("Py_RunMain", sp=700),
    ]
    python = [  # leaf..root
        PyFrame("forward", "model.py", 10, native_sp=290),
        PyFrame("train_step", "loop.py", 55, native_sp=480),
    ]
    merged = stitch(native, python)
    assert merged == ("Py_RunMain", "py::train_step", "py::forward",
                      "at::native::softmax", "memcpy")


def test_stitch_pure_native_passthrough():
    native = [NativeFrame("a", 1), NativeFrame("b", 2)]
    assert stitch(native, []) == ("b", "a")


def _stitch_reference(native, python,
                      evaluator_names=("_PyEval_EvalFrameDefault",)):
    """The pre-refactor O(native x python) matcher, kept as the oracle."""
    py = list(python)
    merged = []
    for nf in native:
        if nf.name in evaluator_names and py:
            best_i, best_sp = None, None
            for i, pf in enumerate(py):
                if pf.native_sp <= nf.sp and (best_sp is None
                                              or pf.native_sp > best_sp):
                    best_i, best_sp = i, pf.native_sp
            if best_i is None:
                best_i = 0
            merged.append(py.pop(best_i).label)
        else:
            merged.append(nf.name)
    for pf in py:
        merged.append(pf.label)
    return tuple(reversed(merged))


def test_stitch_interleaved_evaluator_frames():
    """Evaluator frames interleaved with native frames at every depth;
    the two-pointer matcher must reproduce the old evaluator-by-evaluator
    rescan exactly."""
    ev = "_PyEval_EvalFrameDefault"
    native = [  # leaf..root, SPs ascending as a real unwind produces
        NativeFrame("memcpy", sp=50),
        NativeFrame(ev, sp=100),
        NativeFrame("at::softmax", sp=150),
        NativeFrame(ev, sp=200),
        NativeFrame("launch_kernel", sp=250),
        NativeFrame(ev, sp=300),
        NativeFrame(ev, sp=400),
        NativeFrame("Py_RunMain", sp=500),
    ]
    python = [  # leaf..root
        PyFrame("leaf_fn", "a.py", 1, native_sp=90),
        PyFrame("mid_fn", "a.py", 2, native_sp=190),
        PyFrame("outer_fn", "b.py", 3, native_sp=290),
        PyFrame("main_fn", "b.py", 4, native_sp=390),
    ]
    merged = stitch(native, python)
    assert merged == ("Py_RunMain", "py::main_fn", "py::outer_fn",
                      "launch_kernel", "py::mid_fn", "at::softmax",
                      "py::leaf_fn", "memcpy")
    assert merged == _stitch_reference(native, python)


def test_stitch_two_pointer_matches_reference_randomized():
    """Randomized equivalence incl. degenerate inputs: unmatched python
    frames, equal SPs, out-of-order native walks, leftover frames."""
    import random
    rng = random.Random(1234)
    ev = "_PyEval_EvalFrameDefault"
    for trial in range(400):
        n_native = rng.randint(0, 8)
        monotone = rng.random() < 0.7
        native, sp = [], 0
        for k in range(n_native):
            sp = sp + rng.randint(1, 40) if monotone else rng.randint(0, 300)
            native.append(NativeFrame(
                ev if rng.random() < 0.5 else f"n{k}", sp))
        python = [PyFrame(f"f{j}", "x.py", j, rng.randint(0, 300))
                  for j in range(rng.randint(0, 5))]
        assert stitch(native, python) == _stitch_reference(native, python), \
            (trial, native, python)


def test_walk_real_python_frames():
    def inner():
        return walk_pyframes(sys._getframe())

    def outer():
        return inner()

    frames = outer()
    names = [f.code_name for f in frames]
    assert names[0] == "inner" and "outer" in names
    labels = [f.label for f in frames]
    assert labels[0] == "py::inner"
