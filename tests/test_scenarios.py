"""Scenario/diagnosis-rule registry: registration validation, service
snapshot immutability, rule-driven diffdiag behaviour, wire-format
version negotiation for the extended OS counters, and the scenario
matrix — every registered scenario must produce its expected diagnosis
on all five service paths (legacy, streaming, columnar, sharded, pod
tier over wire v3 sessions)."""
import dataclasses

import pytest

from repro.core import simcluster as sc
from repro.core.diffdiag import cpu_diff, os_diff
from repro.core.events import OSSignals, ProfileBatch
from repro.core.flamegraph import FlameGraph
from repro.core.scenarios import (CPURules, OSRule, RegistryError, Scenario,
                                  SOPRule, ScenarioRegistry,
                                  build_default_registry, default_registry)
from repro.core.service import CentralService
from repro.core.simcluster import SERVICE_PATHS, run_scenario_matrix
from repro.core.trace import (WIRE_VERSION, WireFormatError, decode_batch,
                              encode_batch)


# ---------------------------------------------------------------------------
# registration validation
# ---------------------------------------------------------------------------


def _scenario(name="s1", cause="c1", **kw):
    defaults = dict(
        name=name, description="d", make_fault=lambda: sc.swap_thrash(0),
        expected_cause=cause, expected_layer="os", category="os_interference")
    defaults.update(kw)
    return Scenario(**defaults)


def test_duplicate_scenario_name_raises():
    reg = ScenarioRegistry()
    reg.register_scenario(_scenario())
    with pytest.raises(RegistryError, match="duplicate"):
        reg.register_scenario(_scenario(cause="c2"))


def test_empty_sop_signature_raises():
    reg = ScenarioRegistry()
    with pytest.raises(RegistryError, match="empty signature"):
        reg.register_sop_rule(SOPRule((), "c", "a"))
    with pytest.raises(RegistryError, match="empty signature"):
        reg.register_sop_rule(SOPRule(("fn", ""), "c", "a"))


def test_empty_os_rule_field_raises():
    reg = ScenarioRegistry()
    with pytest.raises(RegistryError):
        reg.register_os_rule(OSRule(cause="c", field="", ratio=2.0))
    with pytest.raises(RegistryError):
        reg.register_os_rule(OSRule(cause="", field="f", ratio=2.0))
    with pytest.raises(RegistryError, match="positive ratio"):
        reg.register_os_rule(OSRule(cause="c", field="f", ratio=0.0))
    # eager validation: a typo'd field must fail at registration, not be
    # silently skipped at diagnosis time
    with pytest.raises(RegistryError, match="unknown OSSignals field"):
        reg.register_os_rule(OSRule(cause="c", field="majro_faults",
                                    ratio=2.0))
    reg.register_os_rule(OSRule(cause="c", field="major_faults", ratio=2.0))


def test_conflicting_category_raises():
    reg = ScenarioRegistry()
    reg.register_scenario(_scenario(cause="c1", category="software"))
    with pytest.raises(RegistryError, match="already mapped"):
        reg.register_scenario(
            _scenario(name="s2", cause="c1", category="network"))


def test_category_lookup_defaults_unknown():
    reg = ScenarioRegistry()
    assert reg.category_for("never_registered") == "unknown"
    assert reg.category_for("logging_overhead") == "software"  # legacy seed


def test_default_registry_has_ten_plus_scenarios():
    reg = build_default_registry()
    assert len(reg) >= 10
    names = {s.name for s in reg}
    # the five §5.4 case studies stay registered
    assert {"gpu_thermal_throttle", "nic_softirq_contention",
            "vfs_dentry_lock_contention", "logging_overhead",
            "storage_io_bottleneck"} <= names
    # plus at least five production-style scenarios
    assert {"memory_pressure_swap", "pcie_link_degradation",
            "cpu_frequency_downclock", "ecc_row_remap_stall",
            "numa_remote_allocation", "dataloader_starvation"} <= names


# ---------------------------------------------------------------------------
# snapshot immutability: a started service is isolated from later edits
# ---------------------------------------------------------------------------


def test_snapshot_is_frozen_and_isolated():
    reg = build_default_registry()
    snap = reg.snapshot()
    assert snap.frozen and not reg.frozen
    with pytest.raises(RegistryError, match="frozen"):
        snap.register_scenario(_scenario(name="late"))
    n = len(snap)
    reg.register_scenario(_scenario(name="late", cause="late_cause"))
    assert len(snap) == n and "late" not in snap


def test_service_pins_registry_at_construction():
    reg = build_default_registry()
    svc = CentralService(registry=reg)
    reg.register_sop_rule(SOPRule(("post_start_fn",), "post_start_cause",
                                  "act"))
    assert svc.rules.frozen
    assert all(r.cause != "post_start_cause" for r in svc.rules.sop_rules)
    assert any(r.cause == "post_start_cause" for r in reg.sop_rules)


# ---------------------------------------------------------------------------
# rule-driven diffdiag: thresholds are data, pinned legacy behaviour
# ---------------------------------------------------------------------------


def test_os_diff_legacy_thresholds_pinned():
    """Regression pin for the original inline thresholds: irq 2x + 1000
    absolute, scheduler 2x (severity = ratio/threshold), numa 4x."""
    h = OSSignals(rank=7, timestamp=0, interrupts={"NET_RX": 2000},
                  sched_latency_p99=80e-6, numa_migrations=10)
    # just below every threshold: quiet
    quiet = OSSignals(rank=0, timestamp=0, interrupts={"NET_RX": 2999},
                      sched_latency_p99=159e-6, numa_migrations=40)
    assert os_diff(quiet, h) is None
    # irq needs BOTH 2x and +1000 absolute: 1900 vs 900 is >2x but +1000
    small_abs = OSSignals(rank=0, timestamp=0, interrupts={"NET_RX": 1900},
                          sched_latency_p99=80e-6)
    assert os_diff(small_abs, dataclasses.replace(h, interrupts={"NET_RX": 900})) is None
    v = os_diff(OSSignals(rank=0, timestamp=0, interrupts={"NET_RX": 8000},
                          sched_latency_p99=80e-6), h)
    assert v and v.root_cause == "irq_imbalance"
    assert v.evidence["irq:NET_RX"] == (8000, 2000)
    assert v.evidence["causes"][0]["severity"] == pytest.approx(2.0)  # 4x/2x
    # scheduler severity normalized by its own 2x threshold
    v = os_diff(dataclasses.replace(h, rank=0, sched_latency_p99=800e-6), h)
    assert v and v.root_cause == "scheduler_contention"
    assert v.evidence["causes"][0]["severity"] == pytest.approx(5.0)  # 10x/2x


def test_os_diff_custom_rules_override_defaults():
    h = OSSignals(rank=7, timestamp=0, sched_latency_p99=80e-6)
    s = dataclasses.replace(h, rank=0, sched_latency_p99=800e-6)
    strict = [OSRule(cause="sched_paranoid", field="sched_latency_p99",
                     ratio=100.0, baseline_floor=1e-6)]
    assert os_diff(s, h, rules=strict) is None
    loose = [OSRule(cause="sched_paranoid", field="sched_latency_p99",
                    ratio=1.5, baseline_floor=1e-6, action="page oncall")]
    v = os_diff(s, h, rules=loose)
    assert v and v.root_cause == "sched_paranoid" and v.action == "page oncall"


def test_os_diff_extended_counters():
    h = OSSignals(rank=7, timestamp=0, major_faults=2, cpu_freq_mhz=2600.0,
                  pcie_replays=1, ecc_remapped_rows=0, numa_remote_ratio=0.03)
    cases = [
        (dict(major_faults=6000), "memory_pressure_swap"),
        (dict(pcie_replays=600), "pcie_link_degradation"),
        (dict(cpu_freq_mhz=1200.0), "cpu_frequency_downclock"),
        (dict(ecc_remapped_rows=8), "ecc_row_remap_stall"),
        (dict(numa_remote_ratio=0.6), "numa_remote_allocation"),
    ]
    for overrides, cause in cases:
        s = dataclasses.replace(h, rank=0, **overrides)
        v = os_diff(s, h)
        assert v and v.root_cause == cause, (overrides, v)
    # healthy-vs-healthy jitter on the extended counters stays quiet
    s = dataclasses.replace(h, rank=0, major_faults=4, pcie_replays=2,
                            cpu_freq_mhz=2580.0, numa_remote_ratio=0.045)
    assert os_diff(s, h) is None


def test_os_diff_unreported_gauge_is_not_a_downclock():
    """A v1 agent reports no cpu_freq_mhz (schema default 0).  The
    lower-is-worse rule must treat 0 as 'unreported' on EITHER side —
    not as an extreme downclock that out-severities every real cause."""
    h = OSSignals(rank=7, timestamp=0, cpu_freq_mhz=2600.0)
    v1_straggler = OSSignals(rank=0, timestamp=0, cpu_freq_mhz=0.0,
                             major_faults=6000)
    v = os_diff(v1_straggler, h)
    assert v is not None and v.root_cause == "memory_pressure_swap"
    assert all(c["cause"] != "cpu_frequency_downclock"
               for c in v.evidence["causes"])
    # unreported on the healthy side is equally not a verdict
    assert os_diff(OSSignals(rank=0, timestamp=0, cpu_freq_mhz=1200.0),
                   OSSignals(rank=7, timestamp=0, cpu_freq_mhz=0.0)) is None


def test_os_diff_dict_rule_honors_lower_is_worse():
    """Dict-valued fields go through the same evaluator as scalars, so
    direction applies per key (e.g. residency where a drop is the fault)."""
    rules = [OSRule(cause="residency_drop", field="softirq_residency",
                    ratio=2.0, baseline_floor=1e-3, lower_is_worse=True)]
    s = OSSignals(rank=0, timestamp=0, softirq_residency={"RCU": 0.01})
    h = OSSignals(rank=7, timestamp=0, softirq_residency={"RCU": 0.10})
    v = os_diff(s, h, rules=rules)
    assert v and v.root_cause == "residency_drop"
    assert v.evidence["softirq_residency:RCU"] == (0.01, 0.10)
    assert os_diff(h, s, rules=rules) is None
    # the extreme case: the counter vanished entirely on the straggler —
    # keys present only on the healthy side still evaluate
    gone = OSSignals(rank=0, timestamp=0, softirq_residency={})
    v = os_diff(gone, h, rules=rules)
    assert v and v.root_cause == "residency_drop"
    assert v.evidence["softirq_residency:RCU"] == (0, 0.10)


def test_cpu_diff_unclassified_noise_descends():
    """Diffuse unclassified deltas below unclassified_min are sampling
    noise, not a CPU diagnosis — the walk must descend to the OS layer."""
    base = {("main", "forward", "softmax"): 400,
            ("main", "backward", "matmul"): 400}
    noisy = {("main", "forward", "softmax"): 404,
             ("main", "backward", "matmul"): 397}
    fg = FlameGraph
    a, b = fg(), fg()
    for st, w in base.items():
        b.add(st, w)
    for st, w in noisy.items():
        a.add(st, w)
    assert cpu_diff(a, b) is None
    # ...but a large unclassified divergence still fires
    a.add(("main", "mystery_daemon"), 40)
    v = cpu_diff(a, b)
    assert v and v.root_cause == "cpu_host_interference"
    # and the floor itself is rule data
    v = cpu_diff(a, b, rules=CPURules(unclassified_min=0.9))
    assert v is None
    # raising the noise floor must NOT deflate confidence on verdicts
    # that clear it — confidence has its own scale
    v = cpu_diff(a, b, rules=CPURules(unclassified_min=0.04))
    assert v and v.confidence == pytest.approx(
        min(1.0, max(a.diff(b).values()) / 0.02))


# ---------------------------------------------------------------------------
# wire-format version negotiation (SYTC v1 <-> v2)
# ---------------------------------------------------------------------------


def _batch(sig):
    cl = sc.SimCluster(n_ranks=1, seed=3)
    prof = cl.step()[0]
    prof.os_signals = sig
    return ProfileBatch("job-v", [prof], "node-v")


def test_wire_v2_round_trips_extended_fields():
    sig = OSSignals(rank=0, timestamp=1.0, interrupts={"LOC": 5},
                    sched_latency_p99=1e-4, major_faults=77,
                    cpu_freq_mhz=1234.5, pcie_replays=9,
                    ecc_remapped_rows=3, numa_remote_ratio=0.25)
    batch = _batch(sig)
    data = encode_batch(batch)
    assert data[4:6] == WIRE_VERSION.to_bytes(2, "little")
    out = decode_batch(data).to_dataclasses()
    assert out.profiles[0].os_signals == sig


def test_wire_v1_downlevel_round_trips_default_fields():
    sig = OSSignals(rank=0, timestamp=1.0, interrupts={"LOC": 5},
                    sched_latency_p99=1e-4)
    batch = _batch(sig)
    data = encode_batch(batch, version=1)
    assert data[4:6] == (1).to_bytes(2, "little")
    out = decode_batch(data).to_dataclasses()
    assert out.profiles[0].os_signals == sig
    assert out == batch


def test_wire_v1_refuses_extended_fields():
    batch = _batch(OSSignals(rank=0, timestamp=0.0, major_faults=5000))
    with pytest.raises(WireFormatError, match="extended OS counters"):
        encode_batch(batch, version=1)


def test_wire_unsupported_versions_rejected():
    batch = _batch(None)
    with pytest.raises(WireFormatError, match="cannot encode"):
        encode_batch(batch, version=0)
    with pytest.raises(WireFormatError, match="cannot encode"):
        encode_batch(batch, version=WIRE_VERSION + 1)
    data = bytearray(encode_batch(batch))
    data[4:6] = (WIRE_VERSION + 1).to_bytes(2, "little")
    with pytest.raises(WireFormatError, match="unsupported wire version"):
        decode_batch(bytes(data))


# ---------------------------------------------------------------------------
# the scenario matrix: every scenario, every service path
# ---------------------------------------------------------------------------

_REGISTRY = default_registry()


@pytest.mark.parametrize(
    "name", sorted(s.name for s in _REGISTRY.scenarios))
def test_scenario_diagnoses_on_all_service_paths(name):
    """The acceptance gate, generalized from the old hand-enumerated
    five-case equivalence tests: each registered scenario's first
    diagnosis is the expected root cause (and straggler rank, where
    pinned) on the legacy, streaming, columnar, sharded and pod paths
    alike — and all five paths agree event for event."""
    scen = _REGISTRY.get(name)
    results = run_scenario_matrix(scenarios=[scen], strict=True)
    per_path = results[name]
    assert set(per_path) == set(SERVICE_PATHS)
    assert all(r.ok for r in per_path.values())
    # every path agrees on the cause AND the category is the scenario's
    causes = {r.first_cause for r in per_path.values()}
    assert causes == {scen.expected_cause}
    assert _REGISTRY.category_for(scen.expected_cause) == scen.category
    # cross-path equivalence: identical diagnoses, event for event
    reference = per_path["streaming"].event_tuples
    assert reference
    for path in SERVICE_PATHS:
        assert per_path[path].event_tuples == reference, path


def test_zero_baseline_delta_does_not_crash_temporal_path():
    """'Report any regression' tuning: baseline_delta=0 must still emit a
    (fully confident) temporal diagnosis, not divide by zero."""
    svc = CentralService(window=50, baseline_delta=0.0)
    cl = sc.SimCluster(n_ranks=8, seed=7)
    cl.run(svc, 30)
    cl.add_fault(sc.logging_overhead())
    events = cl.run(svc, 60)
    assert events and events[0].root_cause == "logging_overhead"
    assert events[0].verdict.confidence == 1.0


def test_matrix_strict_reports_misses():
    bad = _scenario(name="impossible", cause="never_this_cause")
    with pytest.raises(AssertionError, match="impossible/streaming"):
        run_scenario_matrix(scenarios=[bad], paths=("streaming",),
                            strict=True)


def test_registered_scenario_flows_through_custom_registry():
    """A user-registered scenario (new fault + new OS rule) is diagnosed
    end-to-end by a service built from that registry — no core edits."""
    reg = build_default_registry()
    reg.register_os_rule(OSRule(
        cause="cpu_steal_storm", field="cpu_steal", ratio=3.0,
        min_abs_delta=0.05, baseline_floor=0.01,
        action="evict the noisy neighbour VM"))

    def steal_fault():
        def os_fx(sig, rng):
            sig["cpu_steal"] = 0.4 + rng.uniform(-0.02, 0.02)
        return sc.Fault("cpu_steal_storm", [3], os_effect=os_fx,
                        entry_delay=lambda base: 1.0e-3)

    reg.register_scenario(Scenario(
        name="noisy_neighbour_steal",
        description="hypervisor steals cycles from one node",
        make_fault=steal_fault, expected_cause="cpu_steal_storm",
        expected_layer="os", category="os_interference", expected_rank=3))
    res = run_scenario_matrix(
        registry=reg, scenarios=[reg.get("noisy_neighbour_steal")],
        paths=("streaming", "sharded"), strict=True)
    assert all(r.ok for r in res["noisy_neighbour_steal"].values())
