"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; identical kernel code targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


FA_CASES = [
    # (b, hq, hkv, s, d, causal, window, dtype, tol)
    (1, 2, 2, 128, 64, True, 0, jnp.float32, 2e-5),
    (2, 4, 2, 256, 64, True, 0, jnp.float32, 2e-5),
    (1, 8, 1, 128, 32, True, 64, jnp.float32, 2e-5),    # MQA + SWA
    (2, 2, 2, 256, 128, False, 0, jnp.float32, 2e-5),   # bidirectional
    (1, 4, 4, 512, 64, True, 128, jnp.float32, 2e-5),
    (1, 4, 2, 256, 64, True, 0, jnp.bfloat16, 2e-2),
    (1, 2, 1, 128, 128, True, 0, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window,dtype,tol", FA_CASES)
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, window, dtype, tol):
    q = _rand((b, hq, s, d), dtype)
    k = _rand((b, hkv, s, d), dtype)
    v = _rand((b, hkv, s, d), dtype)
    out = ops.flash_attention_bhsd(q, k, v, causal=causal,
                                   sliding_window=window)
    ref = ops.flash_attention_ref(q, k, v, causal=causal,
                                  sliding_window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shapes():
    """Non-default block shapes must not change results."""
    from repro.kernels.flash_attention import flash_attention_fwd
    q = _rand((1, 2, 256, 64), jnp.float32)
    k = _rand((1, 2, 256, 64), jnp.float32)
    v = _rand((1, 2, 256, 64), jnp.float32)
    base = flash_attention_fwd(q, k, v, block_q=128, block_k=128)
    for bq, bk in [(64, 64), (256, 64), (64, 256), (32, 128)]:
        out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5, rtol=2e-5)


SSD_CASES = [
    # (b, nc, L, h, p, n, dtype, tol)
    (1, 2, 32, 2, 16, 8, jnp.float32, 1e-4),
    (2, 3, 64, 4, 32, 16, jnp.float32, 1e-4),
    (1, 4, 128, 2, 64, 32, jnp.float32, 2e-4),
    (1, 2, 64, 4, 32, 16, jnp.bfloat16, 5e-2),
]


@pytest.mark.parametrize("b,nc,L,h,p,n,dtype,tol", SSD_CASES)
def test_ssd_chunk_sweep(b, nc, L, h, p, n, dtype, tol):
    x = _rand((b, nc, L, h, p), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, nc, L, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _rand((b, nc, L, n), dtype)
    C = _rand((b, nc, L, n), dtype)
    yk, stk, cdk, idk = ops.ssd_chunk(x, dt, A, B, C)
    yr, str_, cdr, idr = ops.ssd_chunk_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(str_),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(cdk), np.asarray(cdr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(idk), np.asarray(idr), atol=1e-5)


@pytest.mark.parametrize("rows,d,dtype,tol", [
    (64, 128, jnp.float32, 1e-5),
    (256, 512, jnp.float32, 1e-5),
    (128, 256, jnp.bfloat16, 2e-2),
    (512, 64, jnp.float32, 1e-5),
])
def test_rmsnorm_sweep(rows, d, dtype, tol):
    x = _rand((rows, d), dtype)
    w = _rand((d,), jnp.float32) * 0.1
    out = ops.rmsnorm(x, w)
    ref = ops.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_ssd_kernel_consistent_with_full_scan():
    """Kernel chunk terms + host recurrence == monolithic jnp SSD."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n, chunk = 1, 128, 2, 16, 8, 32
    x = _rand((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _rand((b, s, n), jnp.float32)
    C = _rand((b, s, n), jnp.float32)
    y_ref, st_ref = ssd_chunked(x, dt, A, B, C, chunk, use_pallas=False)
    y_k, st_k = ssd_chunked(x, dt, A, B, C, chunk, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssd_decode_matches_chunked():
    """Sequential ssd_decode_step over S tokens == chunked scan output."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    b, s, h, p, n = 1, 16, 2, 8, 4
    x = _rand((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.3, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = _rand((b, s, n), jnp.float32)
    C = _rand((b, s, n), jnp.float32)
    y_chunk, final = ssd_chunked(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                     B[:, t], C[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chunk),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final),
                               atol=1e-4, rtol=1e-3)
