"""NodeAgent local buffering semantics (§7: tolerate a down/unreachable
central service): drop-oldest beyond the buffer bound, re-buffer on failed
flush, and order preservation across a reconnect."""
from repro.core.agent import AgentConfig, NodeAgent
from repro.core.events import IterationProfile, ProfileBatch


def _profile(i: int, group: str = "g0") -> IterationProfile:
    return IterationProfile(rank=0, iteration=i, group_id=group,
                            iter_time=0.1)


class _RecordingService:
    """Per-profile ingest only (no ingest_batch) — the §4 duck-type."""

    def __init__(self):
        self.seen = []

    def ingest(self, profile, job_id="job-0"):
        self.seen.append(profile.iteration)


class _BatchService(_RecordingService):
    def __init__(self):
        super().__init__()
        self.batches = []

    def ingest_batch(self, batch: ProfileBatch) -> int:
        self.batches.append(batch)
        for p in batch.profiles:
            self.seen.append(p.iteration)
        return len(batch.profiles)


def test_drop_oldest_beyond_buffer_limit():
    agent = NodeAgent(AgentConfig(buffer_limit_s=5.0))
    for i in range(12):
        agent.submit(_profile(i))
    assert agent.dropped == 7
    assert [p.iteration for p in agent._buffer] == [7, 8, 9, 10, 11]


def test_flush_rebuffers_when_service_down():
    agent = NodeAgent(AgentConfig())
    for i in range(3):
        agent.submit(_profile(i))
    assert agent.flush() == 0
    assert agent.uploads == 0
    # nothing lost, order intact
    assert [p.iteration for p in agent._buffer] == [0, 1, 2]
    # a second failed flush still does not drop or reorder
    assert agent.flush() == 0
    assert [p.iteration for p in agent._buffer] == [0, 1, 2]


def test_flush_after_reconnect_preserves_submission_order():
    agent = NodeAgent(AgentConfig())
    agent.submit(_profile(0))
    agent.submit(_profile(1))
    agent.flush()                       # service down: re-buffered
    agent.submit(_profile(2))           # submitted while disconnected
    svc = _RecordingService()
    agent.service = svc                 # reconnect
    assert agent.flush() == 3
    assert svc.seen == [0, 1, 2]
    assert agent.uploads == 3
    assert agent._buffer == []


def test_flush_uses_batch_upload_when_available():
    svc = _BatchService()
    agent = NodeAgent(AgentConfig(job_id="job-7"), service=svc)
    for i in range(4):
        agent.submit(_profile(i))
    assert agent.flush() == 4
    assert len(svc.batches) == 1
    assert svc.batches[0].job_id == "job-7"
    assert svc.seen == [0, 1, 2, 3]


def test_flush_rebuffers_remainder_when_service_raises():
    class _Flaky(_RecordingService):
        def __init__(self, fail_after):
            super().__init__()
            self.fail_after = fail_after

        def ingest(self, profile, job_id="job-0"):
            if len(self.seen) >= self.fail_after:
                raise ConnectionError("service went away")
            super().ingest(profile, job_id)

    svc = _Flaky(fail_after=2)
    agent = NodeAgent(AgentConfig(), service=svc)
    for i in range(5):
        agent.submit(_profile(i))
    assert agent.flush() == 2                   # 2 made it, then the drop
    assert agent.upload_failures == 1
    assert svc.seen == [0, 1]
    assert [p.iteration for p in agent._buffer] == [2, 3, 4]
    svc.fail_after = 100                        # service recovers
    assert agent.flush() == 3
    assert svc.seen == [0, 1, 2, 3, 4]          # order preserved, no loss


def test_drop_then_flush_keeps_newest():
    svc = _RecordingService()
    agent = NodeAgent(AgentConfig(buffer_limit_s=3.0))
    for i in range(6):
        agent.submit(_profile(i))
    agent.service = svc
    assert agent.flush() == 3
    assert svc.seen == [3, 4, 5]
    assert agent.dropped == 3
