"""NodeAgent local buffering semantics (§7: tolerate a down/unreachable
central service): drop-oldest beyond the buffer bound, re-buffer on failed
flush, and order preservation across a reconnect."""
from repro.core.agent import AgentConfig, NodeAgent
from repro.core.events import IterationProfile, ProfileBatch


def _profile(i: int, group: str = "g0") -> IterationProfile:
    return IterationProfile(rank=0, iteration=i, group_id=group,
                            iter_time=0.1)


class _RecordingService:
    """Per-profile ingest only (no ingest_batch) — the §4 duck-type."""

    def __init__(self):
        self.seen = []

    def ingest(self, profile, job_id="job-0"):
        self.seen.append(profile.iteration)


class _BatchService(_RecordingService):
    def __init__(self):
        super().__init__()
        self.batches = []

    def ingest_batch(self, batch: ProfileBatch) -> int:
        self.batches.append(batch)
        for p in batch.profiles:
            self.seen.append(p.iteration)
        return len(batch.profiles)


def test_drop_oldest_beyond_buffer_limit():
    agent = NodeAgent(AgentConfig(buffer_limit_s=5.0))
    for i in range(12):
        agent.submit(_profile(i))
    assert agent.dropped == 7
    assert [p.iteration for p in agent._buffer] == [7, 8, 9, 10, 11]


def test_flush_rebuffers_when_service_down():
    agent = NodeAgent(AgentConfig())
    for i in range(3):
        agent.submit(_profile(i))
    assert agent.flush() == 0
    assert agent.uploads == 0
    # nothing lost, order intact
    assert [p.iteration for p in agent._buffer] == [0, 1, 2]
    # a second failed flush still does not drop or reorder
    assert agent.flush() == 0
    assert [p.iteration for p in agent._buffer] == [0, 1, 2]


def test_flush_after_reconnect_preserves_submission_order():
    agent = NodeAgent(AgentConfig())
    agent.submit(_profile(0))
    agent.submit(_profile(1))
    agent.flush()                       # service down: re-buffered
    agent.submit(_profile(2))           # submitted while disconnected
    svc = _RecordingService()
    agent.service = svc                 # reconnect
    assert agent.flush() == 3
    assert svc.seen == [0, 1, 2]
    assert agent.uploads == 3
    assert agent._buffer == []


def test_flush_uses_batch_upload_when_available():
    svc = _BatchService()
    agent = NodeAgent(AgentConfig(job_id="job-7"), service=svc)
    for i in range(4):
        agent.submit(_profile(i))
    assert agent.flush() == 4
    assert len(svc.batches) == 1
    assert svc.batches[0].job_id == "job-7"
    assert svc.seen == [0, 1, 2, 3]


def test_flush_rebuffers_remainder_when_service_raises():
    class _Flaky(_RecordingService):
        def __init__(self, fail_after):
            super().__init__()
            self.fail_after = fail_after

        def ingest(self, profile, job_id="job-0"):
            if len(self.seen) >= self.fail_after:
                raise ConnectionError("service went away")
            super().ingest(profile, job_id)

    svc = _Flaky(fail_after=2)
    agent = NodeAgent(AgentConfig(), service=svc)
    for i in range(5):
        agent.submit(_profile(i))
    assert agent.flush() == 2                   # 2 made it, then the drop
    assert agent.upload_failures == 1
    assert svc.seen == [0, 1]
    assert [p.iteration for p in agent._buffer] == [2, 3, 4]
    svc.fail_after = 100                        # service recovers
    assert agent.flush() == 3
    assert svc.seen == [0, 1, 2, 3, 4]          # order preserved, no loss


def test_drop_then_flush_keeps_newest():
    svc = _RecordingService()
    agent = NodeAgent(AgentConfig(buffer_limit_s=3.0))
    for i in range(6):
        agent.submit(_profile(i))
    agent.service = svc
    assert agent.flush() == 3
    assert svc.seen == [3, 4, 5]
    assert agent.dropped == 3


def test_encoded_retry_is_byte_identical_and_allocation_free():
    """§7 + wire v3: a failed encoded upload re-buffers the already-
    interned columnar views (no re-interning, no new column arrays) and
    the retry re-encodes the *identical bytes* — session watermarks only
    advance on commit, so the receiver can apply either attempt."""
    from repro.core.trace import ColumnarProfile, decode_batch

    class _FlakyEncoded:
        def __init__(self):
            self.frames = []
            self.fail_next = True

        def ingest_encoded(self, data) -> int:
            if self.fail_next:
                self.fail_next = False
                # capture what the failed attempt would have sent
                self.failed_frame = bytes(data)
                raise ConnectionError("upload interrupted")
            self.frames.append(bytes(data))
            return 1

    svc = _FlakyEncoded()
    agent = NodeAgent(AgentConfig(), service=svc)
    for i in range(3):
        agent.submit(_profile(i))
    assert agent.flush() == 0
    assert agent.upload_failures == 1
    # what is re-buffered is the interned columnar view, not dataclasses
    rebuffered = list(agent._buffer)
    assert all(isinstance(p, ColumnarProfile) for p in rebuffered)
    assert [p.iteration for p in rebuffered] == [0, 1, 2]

    assert agent.flush() == 3
    assert agent.uploads == 3 and agent.encoded_uploads == 1
    # the retry shipped exactly the bytes the failed attempt held
    assert svc.frames == [svc.failed_frame]
    # and no new column objects were built for the retry: the encoded
    # frame decodes back to the same profiles the first attempt carried
    out = decode_batch(svc.frames[0])
    assert [p.iteration for p in out.profiles] == [0, 1, 2]
    # identity: the buffered views were reused, not re-interned copies
    second = agent._columnar_batch(rebuffered)
    assert all(a is b for a, b in zip(second.profiles, rebuffered))


def test_encoded_session_resync_after_receiver_restart():
    """A receiver that lost the dictionary session answers with
    WireFormatError; the agent resets and the next flush opens a fresh
    self-contained session the new receiver can decode."""
    from repro.core.service import CentralService
    from repro.core.trace import WireFormatError

    svc = CentralService()
    agent = NodeAgent(AgentConfig(), service=svc)
    agent.submit(_profile(0))
    assert agent.flush() == 1

    # receiver restarts: fresh service, no session state
    class _Restarted:
        def __init__(self, inner):
            self.inner = inner

        def ingest_encoded(self, data) -> int:
            return self.inner.ingest_encoded(data)

    agent.service = _Restarted(CentralService())
    agent.submit(_profile(1))
    assert agent.flush() == 0                   # mid-session frame refused
    assert agent.session_resyncs == 1
    assert agent.upload_failures == 1
    fresh = CentralService()
    agent.service = fresh
    assert agent.flush() == 1                   # self-contained reopen
    assert agent.session_resyncs == 1
