"""Hypothesis property tests for the batched collection path: for any
generated process/chain mix, ``unwind_batch`` must be byte-identical to
the scalar Algorithm-1 loop — same PC lists AND same final ``MarkerMap``
state — including repeated samples (memo hits) and partially registered
binaries."""
import random

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.unwind import HybridUnwinder, SimProcess, SimThread
from repro.core.unwind.procmodel import Binary, FunctionDef

settings.register_profile("collection", max_examples=40, deadline=None)
settings.load_profile("collection")


@st.composite
def _process_and_chains(draw):
    n_bins = draw(st.integers(1, 3))
    binaries = []
    for bi in range(n_bins):
        n_fn = draw(st.integers(1, 12))
        funcs, off = [], 0x1000
        for fi in range(n_fn):
            size = draw(st.sampled_from((64, 256, 512)))
            funcs.append(FunctionDef(
                name=f"b{bi}::f{fi}", offset=off, size=size,
                omits_fp=draw(st.booleans()),
                frame_size=draw(st.sampled_from((32, 48, 96))),
                complex_fde=draw(st.booleans())
                and draw(st.integers(0, 9)) == 0))
            off += size + draw(st.sampled_from((0, 0, 128)))  # gaps too
        binaries.append(Binary(name=f"bin{bi}", build_id=f"bid{bi}" * 8,
                               functions=funcs, size=off))
    registered = draw(st.lists(st.integers(0, n_bins - 1), min_size=0,
                               max_size=n_bins, unique=True))
    n_threads = draw(st.integers(1, 12))
    chains = []
    for _ in range(n_threads):
        depth = draw(st.integers(1, 8))
        chain = []
        for _ in range(depth):
            b = binaries[draw(st.integers(0, n_bins - 1))]
            chain.append((b, b.functions[
                draw(st.integers(0, len(b.functions) - 1))]))
        chains.append(chain)
    repeat = draw(st.lists(st.integers(0, n_threads - 1), min_size=0,
                           max_size=8))
    seed = draw(st.integers(0, 2**20))
    return binaries, registered, chains, repeat, seed


@given(_process_and_chains())
def test_batch_equals_scalar_property(case):
    """Byte-identical stacks + final MarkerMap state vs scalar."""
    binaries, registered, chains, repeat, seed = case
    proc = SimProcess()
    for b in binaries:
        proc.mmap_binary(b)
    uw_s, uw_b = HybridUnwinder(), HybridUnwinder()
    for i in registered:
        uw_s.register_binary(binaries[i])
        uw_b.register_binary(binaries[i])
    threads = []
    for ci, chain in enumerate(chains):
        t = SimThread(proc, random.Random(seed + ci))
        t.call_chain(chain)
        threads.append(t)
    sched = threads + [threads[i] for i in repeat]
    scalar = [uw_s.unwind(t) for t in sched]
    batch = uw_b.unwind_batch(sched)
    assert batch == scalar
    assert uw_b.markers._map == uw_s.markers._map
