"""Cross-layer causal attribution: blame timelines, per-collective
blame edges, cascade localization across overlapping communication
groups, verdict provenance, and equivalence with the pre-attribution
pairwise path where no cascade exists."""
import dataclasses

import numpy as np
import pytest

from repro.core import simcluster as sc
from repro.core.attribution import (CASCADE_EXPORT_CAUSE, BlameTimeline,
                                    CascadeExport, iteration_timelines,
                                    iteration_timelines_naive,
                                    localize_cascades)
from repro.core.events import CollectiveEvent
from repro.core.diffdiag import Verdict
from repro.core.service import CentralService, DiagnosticEvent
from repro.core.sharded import ShardedService
from repro.core.straggler import (BlameEdge, GroupBlame, StragglerAlert,
                                  StragglerDetector)
from repro.core.trace import ColumnarProfile, TraceTables
from repro.ft.mitigation import MitigationPlanner


# ---------------------------------------------------------------------------
# blame timelines
# ---------------------------------------------------------------------------


def _profile(tables, rank, *, group="g0", iter_time=1.0, colls=(),
             kernels=(), stacks=(), iteration=0):
    """colls: (op, entry, exit); kernels: (start, dur); stacks:
    (frames tuple, weight)."""
    intern = tables.strings.intern
    return ColumnarProfile(
        rank=rank, iteration=iteration, group_id=group,
        iter_time=iter_time, tables=tables,
        stack_ts=np.zeros(len(stacks)),
        stack_weight=np.array([w for _f, w in stacks], dtype=np.int64),
        stack_kind=np.full(len(stacks), intern("cpu"), dtype=np.int64),
        stack_id=np.array([tables.intern_stack(f) for f, _w in stacks],
                          dtype=np.int64),
        kern_name=np.array([intern(f"k{i}") for i in range(len(kernels))],
                           dtype=np.int64),
        kern_start=np.array([s for s, _d in kernels], dtype=np.float64),
        kern_dur=np.array([d for _s, d in kernels], dtype=np.float64),
        kern_stream=np.zeros(len(kernels), dtype=np.int64),
        coll_op=np.array([intern(op) for op, _e, _x in colls],
                         dtype=np.int64),
        coll_group=np.full(len(colls), intern(group), dtype=np.int64),
        coll_entry=np.array([e for _o, e, _x in colls], dtype=np.float64),
        coll_exit=np.array([x for _o, _e, x in colls], dtype=np.float64),
        coll_nbytes=np.zeros(len(colls), dtype=np.int64),
        coll_dev_dur=np.zeros(len(colls)),
        coll_instance=np.full(len(colls), -1, dtype=np.int64),
        coll_seq=np.full(len(colls), -1, dtype=np.int64))


def test_wait_blamed_on_latest_enterer():
    """Barrier semantics: ranks 0/1 enter early and wait; rank 2 enters
    last and is the culprit of every edge — its own wait is zero."""
    t = TraceTables()
    profs = [
        _profile(t, 0, colls=[("AllReduce", 0.10, 0.45)]),
        _profile(t, 1, colls=[("AllReduce", 0.20, 0.45)]),
        _profile(t, 2, colls=[("AllReduce", 0.40, 0.45)]),
    ]
    tls, edges = iteration_timelines(profs)
    by_rank = {x.rank: x for x in tls}
    assert by_rank[0].blocked_wait == pytest.approx(0.30)
    assert by_rank[1].blocked_wait == pytest.approx(0.20)
    assert by_rank[2].blocked_wait == 0.0
    # transfer = in-collective time after the instance started
    assert by_rank[0].transfer == pytest.approx(0.05)
    assert by_rank[2].transfer == pytest.approx(0.05)
    assert {(e.culprit_rank, e.victim_rank) for e in edges} == \
        {(2, 0), (2, 1)}
    assert all(e.group_id == "g0" and e.op == "AllReduce" for e in edges)


def test_components_sum_to_iter_time_and_exposed_compute():
    t = TraceTables()
    p = _profile(
        t, 0, iter_time=1.0,
        colls=[("AllReduce", 0.5, 0.7)],
        kernels=[(0.0, 0.3), (0.45, 0.15)],   # second overlaps [0.5,0.6]
        stacks=[(("main", "train"), 3), (("ncclAllReduce",), 1)])
    q = _profile(t, 1, iter_time=1.0, colls=[("AllReduce", 0.6, 0.7)])
    tls, _ = iteration_timelines([p, q])
    tl = next(x for x in tls if x.rank == 0)
    # kernel time 0.45 minus 0.10 overlapping the collective
    assert tl.compute == pytest.approx(0.35)
    assert tl.blocked_wait == pytest.approx(0.1)      # waited on rank 1
    assert tl.transfer == pytest.approx(0.1)
    # remainder 0.45 split by stack evidence: 3/4 host, 1/4 residual
    assert tl.host == pytest.approx(0.45 * 0.75)
    assert tl.residual == pytest.approx(0.45 * 0.25)
    assert tl.total == pytest.approx(tl.iter_time)
    # profile-level interval view agrees
    assert p.exposed_kernel_time() == pytest.approx(0.35)


def test_over_budget_components_scale_down():
    """Measured parts exceeding iter_time scale down proportionally, so
    the sum invariant holds even for inconsistent inputs."""
    t = TraceTables()
    p = _profile(t, 0, iter_time=0.1, kernels=[(0.0, 0.3)],
                 colls=[("AllReduce", 0.4, 0.5)])
    q = _profile(t, 1, iter_time=0.1, colls=[("AllReduce", 0.45, 0.5)])
    tls, _ = iteration_timelines([p, q])
    tl = next(x for x in tls if x.rank == 0)
    assert tl.total == pytest.approx(tl.iter_time)
    assert tl.residual == 0.0 and tl.host == 0.0


def test_vectorized_matches_naive_on_sim_iteration():
    t = TraceTables()
    cl = sc.SimCluster(n_ranks=12, seed=5, columnar=True, tables=t,
                       stack_variants=3)
    cl.add_fault(sc.nic_softirq(4))
    profs = cl.step()
    cl2 = sc.SimCluster(n_ranks=12, seed=5, columnar=False,
                        stack_variants=3)
    cl2.add_fault(sc.nic_softirq(4))
    tls, edges = iteration_timelines(profs)
    tls_n, edges_n = iteration_timelines_naive(cl2.step())
    for a, b in zip(tls, tls_n):
        assert (a.rank, a.group_id) == (b.rank, b.group_id)
        assert a.components() == pytest.approx(b.components(), abs=1e-9)
        assert a.total == pytest.approx(a.iter_time)
    assert [(e.culprit_rank, e.victim_rank) for e in edges] == \
        [(e.culprit_rank, e.victim_rank) for e in edges_n]
    assert all(e.culprit_rank == 4 for e in edges)


def test_skew_callable_realigns_entries():
    t = TraceTables()
    profs = [
        _profile(t, 0, colls=[("AllReduce", 0.10, 0.45)]),
        _profile(t, 1, colls=[("AllReduce", 0.40, 0.45)]),
    ]
    # rank 1's clock runs 0.35 ahead: aligned, rank 1 entered EARLIER
    skew = lambda rank, gid: 0.35 if rank == 1 else 0.0
    _tls, edges = iteration_timelines(profs, skew=skew)
    assert {(e.culprit_rank, e.victim_rank) for e in edges} == {(0, 1)}


# ---------------------------------------------------------------------------
# detector: blame edges + summaries, alerts as a view
# ---------------------------------------------------------------------------


def _instance(group, entries, exit_=1.0, op="AllReduce"):
    return [CollectiveEvent(rank=r, group_id=group, op=op, entry=e,
                            exit=exit_) for r, e in entries.items()]


def test_detector_emits_blame_edges_and_summary():
    det = StragglerDetector(window=20, min_instances=4)
    for i in range(6):
        entries = {r: i + r * 1e-5 for r in range(7)}
        entries[7] = i + 0.004                   # the straggler
        det.observe_instance(_instance("gA", entries, exit_=i + 0.01))
    edges = det.drain_edges()
    assert edges and all(isinstance(e, BlameEdge) for e in edges)
    assert all(e.culprit_rank == 7 for e in edges)
    assert {e.victim_rank for e in edges} == set(range(7))
    assert max(e.wait for e in edges) == pytest.approx(0.004, abs=1e-6)
    s = det.blame_summary("gA")
    assert isinstance(s, GroupBlame)
    assert s.culprit_rank == 7 and s.ranks == tuple(range(8))
    assert s.wait[0] == pytest.approx(0.004, abs=1e-6)
    assert s.wait[7] == pytest.approx(0.0, abs=1e-6)
    assert s.instances == 6
    # alerts are a view over the same windowed blame state
    alerts = det.check()
    assert alerts and alerts[0].rank == s.culprit_rank
    assert alerts[0].lateness == pytest.approx(s.culprit_lateness)
    det.forget_group("gA")
    assert det.blame_summary("gA") is None and not det.drain_edges()


# ---------------------------------------------------------------------------
# cascade localization
# ---------------------------------------------------------------------------


def _summary(group, culprit, lateness, *, ranks, wait=None, last_start=0.0):
    lat = {r: (lateness if r == culprit else 0.0) for r in ranks}
    return GroupBlame(
        group_id=group, ranks=tuple(sorted(ranks)), culprit_rank=culprit,
        culprit_lateness=lateness, lateness=lat, wait=wait or {},
        peer_wait=0.0, last_start=last_start, instances=50)


def _alert(group, rank, lateness):
    return StragglerAlert(group, rank, lateness, 0.0, 1e-5, 5.0, 50)


def test_localize_identity_without_cascade():
    alerts = [_alert("gA", 3, 2e-3)]
    summaries = {"gA": _summary("gA", 3, 2e-3, ranks=range(8),
                                wait={r: 2e-3 for r in range(8) if r != 3})}
    locs, exports = localize_cascades(alerts, summaries)
    assert not exports
    assert len(locs) == 1
    loc = locs[0]
    assert (loc.root_group, loc.root_rank) == ("gA", 3)
    assert loc.chain == ("gA",) and loc.alert is alerts[0]
    assert loc.victim_ranks == tuple(r for r in range(8) if r != 3)


def test_localize_follows_victim_bridge_to_root():
    """gB's culprit (7) is a victim in earlier gA; the root is gA's own
    culprit 1.  gB becomes an export pointing at gA."""
    summaries = {
        "gA": _summary("gA", 1, 1.5e-3, ranks=range(8),
                       wait={7: 1.5e-3}, last_start=0.070),
        "gB": _summary("gB", 7, 1.3e-3, ranks=[7, 8, 9, 10],
                       wait={}, last_start=0.082),
    }
    alerts = [_alert("gA", 1, 1.5e-3), _alert("gB", 7, 1.3e-3)]
    locs, exports = localize_cascades(alerts, summaries)
    assert len(locs) == 1
    loc = locs[0]
    assert (loc.root_group, loc.root_rank) == ("gA", 1)
    assert set(loc.affected_groups) == {"gA", "gB"}
    assert 7 in loc.victim_ranks
    assert len(exports) == 1
    exp = exports[0]
    assert isinstance(exp, CascadeExport)
    assert (exp.group_id, exp.via_rank, exp.root_group, exp.root_rank) \
        == ("gB", 7, "gA", 1)


def test_localize_same_culprit_dedupes_to_earliest_group():
    """A rank in two groups, slow in both (NIC flap): one root in the
    earlier group, the later group exports."""
    summaries = {
        "gA": _summary("gA", 4, 0.6e-3, ranks=range(8), last_start=0.070),
        "gB": _summary("gB", 4, 0.6e-3, ranks=[4, 8, 9, 10],
                       last_start=0.082),
    }
    alerts = [_alert("gB", 4, 0.6e-3), _alert("gA", 4, 0.6e-3)]
    locs, exports = localize_cascades(alerts, summaries)
    assert len(locs) == 1
    assert (locs[0].root_group, locs[0].root_rank) == ("gA", 4)
    # the root group's own alert is preferred over the triggering one
    assert locs[0].alert.group_id == "gA"
    assert [e.group_id for e in exports] == ["gB"]


def test_localize_dedupes_exports_and_synthesizes_root_alert():
    """Two flagged ranks in one victim group yield ONE export per
    (victim group, root); a root group that never alerted itself gets a
    summary-derived synthetic alert so the root event's evidence names
    the root, not the triggering victim."""
    summaries = {
        "gA": _summary("gA", 1, 1.5e-3, ranks=range(8),
                       wait={7: 1.5e-3, 6: 1.5e-3}, last_start=0.070),
        "gB": _summary("gB", 7, 1.3e-3, ranks=[6, 7, 8, 9],
                       wait={}, last_start=0.082),
    }
    # gB flags both bridges; gA raised no alert of its own
    summaries["gB"].lateness[6] = 1.2e-3
    alerts = [_alert("gB", 7, 1.3e-3), _alert("gB", 6, 1.2e-3)]
    locs, exports = localize_cascades(alerts, summaries)
    assert len(exports) == 1 and exports[0].group_id == "gB"
    assert len(locs) == 1
    loc = locs[0]
    assert (loc.root_group, loc.root_rank) == ("gA", 1)
    # synthetic alert is root-consistent
    assert (loc.alert.group_id, loc.alert.rank) == ("gA", 1)
    assert loc.alert.lateness == pytest.approx(1.5e-3)


def test_localize_guards_against_coincidental_rank_reuse():
    """Independent groups reusing local rank ids 0..7 must not fabricate
    cascade edges: the candidate neither precedes the victim by the
    margin nor explains its lateness with an upstream wait."""
    summaries = {
        "gA": _summary("gA", 4, 1.5e-3, ranks=range(8),
                       wait={r: 1.4e-3 for r in range(8) if r != 4},
                       last_start=0.0715),
        # same rank ids, its own unrelated culprit, near-identical phase
        "gB": _summary("gB", 2, 1.4e-3, ranks=range(8),
                       wait={r: 1.3e-3 for r in range(8) if r != 2},
                       last_start=0.0712),
    }
    alerts = [_alert("gA", 4, 1.5e-3), _alert("gB", 2, 1.4e-3)]
    locs, exports = localize_cascades(alerts, summaries)
    assert not exports
    assert {(l.root_group, l.root_rank) for l in locs} == \
        {("gA", 4), ("gB", 2)}


# ---------------------------------------------------------------------------
# service-level: cascade scenarios end-to-end + provenance
# ---------------------------------------------------------------------------


def _drive_cascade(svc, scen, baseline=30, fault=60):
    cl = scen.make_cluster(seed=7, columnar=False, native_unwind=False)
    for phase, iters in (("baseline", baseline), ("fault", fault)):
        if phase == "fault":
            cl.add_fleet_fault(scen.make_fault())
        for _ in range(iters):
            for p in cl.step():
                svc.ingest(p)
            if cl.iteration % 10 == 0:
                svc.process()
        svc.process()
    return cl


def test_cascade_root_event_carries_provenance():
    from repro.core.scenarios import default_registry
    reg = default_registry()
    scen = reg.get("cascade_swap_root_node")
    svc = CentralService(window=50, registry=reg)
    cl = _drive_cascade(svc, scen)
    gids = cl.group_ids()
    roots = [e for e in svc.events
             if e.root_cause == "memory_pressure_swap"]
    assert roots
    ev = roots[0]
    assert ev.group_id == gids[0] and ev.straggler_rank == 1
    v = ev.verdict
    assert v.culprit_rank == 1 and v.culprit_group == gids[0]
    assert 7 in v.victim_ranks        # the bridge rank waited on the root
    cascade = ev.evidence["cascade"]
    assert set(cascade["affected_groups"]) == set(gids)
    assert cascade["root_node"] == 0
    # the root rank's blame timeline rides the evidence
    assert ev.evidence["blame_timeline"]["iter_time"] > 0
    exports = [e for e in svc.events
               if e.root_cause == CASCADE_EXPORT_CAUSE]
    assert exports and all(e.group_id == gids[1] for e in exports)
    x = exports[0]
    assert x.verdict.layer == "cascade"
    assert x.verdict.evidence["exported_to"] == gids[0]
    assert x.verdict.culprit_group == gids[0]
    assert x.straggler_rank == 7 and x.category == "network"


def test_sharded_cascade_matches_central():
    """Blame chains cross shard boundaries: the sharded facade must
    produce exactly the central service's cascade diagnoses."""
    from repro.core.scenarios import default_registry
    reg = default_registry()
    scen = reg.get("cascade_victim_group_export")

    def tuples(svc):
        _drive_cascade(svc, scen)
        return [(e.group_id, e.root_cause, e.category, e.straggler_rank)
                for e in svc.events]

    central = tuples(CentralService(window=50, registry=reg))
    sharded = tuples(ShardedService(n_shards=4, window=50, registry=reg))
    assert central and sharded == central
    assert any(c == CASCADE_EXPORT_CAUSE for _g, c, _cat, _r in central)


def test_attribution_off_equals_legacy_pairwise_when_no_cascade():
    """Single-group scenario: attribution on/off produce identical
    event tuples and verdict cores — localization is the identity."""
    def drive(attribution):
        svc = CentralService(window=50, attribution=attribution)
        cl = sc.SimCluster(n_ranks=8, seed=7)
        cl.run(svc, 30)
        cl.add_fault(sc.nic_softirq(4, start=30))
        cl.run(svc, 60)
        return svc.events

    on, off = drive(True), drive(False)
    assert on and len(on) == len(off)
    for a, b in zip(on, off):
        assert (a.group_id, a.root_cause, a.category, a.straggler_rank) \
            == (b.group_id, b.root_cause, b.category, b.straggler_rank)
        assert (a.verdict.layer, a.verdict.root_cause, a.verdict.action) \
            == (b.verdict.layer, b.verdict.root_cause, b.verdict.action)
        assert a.verdict.confidence == pytest.approx(b.verdict.confidence)
    # provenance is the only addition on the attribution path
    assert on[0].verdict.culprit_rank == 4
    assert off[0].verdict.culprit_rank is None


# ---------------------------------------------------------------------------
# mitigation consumes the provenance
# ---------------------------------------------------------------------------


def _event(category, rank, verdict):
    return DiagnosticEvent(
        job_id="j", group_id="gB", category=category, root_cause=verdict.root_cause,
        verdict=verdict, straggler_rank=rank, detected_at=0.0,
        diagnosis_latency_s=0.0)


def test_mitigation_never_cordons_cascade_victims():
    planner = MitigationPlanner()
    victim = Verdict(layer="cascade", root_cause=CASCADE_EXPORT_CAUSE,
                     confidence=0.8, evidence={}, culprit_rank=1,
                     culprit_group="gA", victim_ranks=(7,))
    acts = planner.on_diagnosis(_event("network", 7, victim))
    assert [a.kind for a in acts] == ["observe"]
    assert acts[0].target_nodes == [] and "gA" in acts[0].reason


def test_mitigation_cordons_localized_culprit_node():
    planner = MitigationPlanner(chips_per_node=8)
    root = Verdict(layer="os", root_cause="ecc_row_remap_stall",
                   confidence=0.7, evidence={}, culprit_rank=17,
                   culprit_group="gB", victim_ranks=(0, 1))
    acts = planner.on_diagnosis(_event("gpu_hardware", 17, root))
    assert [a.kind for a in acts] == ["cordon"]
    assert acts[0].target_nodes == [17 // 8]
