"""Hypothesis property-based tests on system invariants."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.aggregate import StackAggregator
from repro.core.collective.instances import separate_instances
from repro.core.events import CollectiveEvent, RawStackSample, StackSample
from repro.core.flamegraph import FlameGraph
from repro.core.straggler import StragglerDetector
from repro.core.symbols import SymbolFile
from repro.core.waterline import CPUWaterline
from repro.models.layers import cross_entropy
from repro.optim.compress import dequantize_int8, quantize_int8
from repro.roofline.hlo import shape_bytes

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
@given(st.lists(st.lists(st.integers(0, 30), min_size=1, max_size=10),
                min_size=1, max_size=60),
       st.integers(1, 8))
def test_aggregation_conserves_counts(stacks, max_entries):
    agg = StackAggregator(max_entries=max_entries)
    total = 0
    for s in stacks:
        frames = tuple(("bid", o) for o in s)
        agg.record(RawStackSample(rank=0, timestamp=0, frames=frames))
        total += 1
    out = agg.drain()
    assert sum(c for _, c in out) == total


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=600))
def test_quantize_dequantize_bounded_error(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, s = quantize_int8(x, block=64)
    dec = dequantize_int8(q, s, x.shape)
    bound = float(jnp.max(jnp.abs(x))) / 127 + 1e-5
    assert float(jnp.max(jnp.abs(dec - x))) <= bound


@given(st.integers(2, 12), st.integers(1, 30), st.integers(0, 1000))
def test_instance_separation_partitions_events(n_ranks, n_inst, seed):
    rng = np.random.default_rng(seed)
    events = []
    for i in range(n_inst):
        t0 = i * 1.0
        entries = t0 + rng.uniform(0, 0.2, n_ranks)
        exit_t = entries.max() + 0.3
        for r in range(n_ranks):
            events.append(CollectiveEvent(
                rank=r, group_id="g", op="AllReduce",
                entry=float(entries[r]), exit=float(exit_t)))
    rng.shuffle(events)
    instances = separate_instances(events)
    # partition property: every event in exactly one instance
    assert sum(len(i) for i in instances) == len(events)
    for inst in instances:
        ranks = [e.rank for e in inst]
        assert len(ranks) == len(set(ranks))       # <=1 event per rank
        lo = max(e.entry for e in inst)
        hi = min(e.exit for e in inst)
        assert lo <= hi + 1e-12                    # mutual overlap invariant


@given(st.lists(st.tuples(st.integers(0, 1 << 30),
                          st.text(min_size=1, max_size=20)),
                min_size=1, max_size=200, unique_by=lambda t: t[0]))
def test_symbol_file_resolves_exact_addresses(syms):
    sf = SymbolFile.build(syms)
    for addr, name in syms:
        assert sf.resolve(addr) == name


@given(st.dictionaries(
    st.tuples(st.sampled_from(["a", "b", "c", "d"]),
              st.sampled_from(["x", "y", "z"])),
    st.integers(1, 100), min_size=1, max_size=10))
def test_flamegraph_fraction_invariants(weights):
    fg = FlameGraph()
    for stack, w in weights.items():
        fg.add(stack, w)
    fr = fg.function_fractions()
    assert all(0 <= v <= 1 + 1e-12 for v in fr.values())
    leaf = fg.leaf_fractions()
    assert abs(sum(leaf.values()) - 1.0) < 1e-9
    d = fg.diff(fg)
    assert all(abs(v) < 1e-12 for v in d.values())


@given(st.integers(2, 16), st.integers(1, 40))
def test_waterline_never_flags_identical_ranks(n_ranks, iters):
    wl = CPUWaterline(window=50)
    fg = FlameGraph()
    fg.add(("main", "work"), 100)
    for _ in range(iters):
        for r in range(n_ranks):
            wl.observe(r, fg)
    assert wl.flagged_ranks() == []


@given(st.integers(8, 16), st.floats(2e-4, 1e-2))
def test_straggler_single_outlier_always_found(n_ranks, lateness):
    """Paper §3.1: for N >= 8 one straggler's influence on mu/sigma is
    bounded, so the outlier remains above mu + 2 sigma.  (For N <= 5 the
    max attainable z-score sqrt(N-1) < 2 — a structural limit of the
    mean/std model; the robust MAD variant covers small groups.)"""
    det = StragglerDetector(window=50, min_instances=8)
    for i in range(20):
        base = i * 0.1
        evs = []
        entries = {r: base + (lateness if r == 1 else 0.0) + (r * 1e-7)
                   for r in range(n_ranks)}
        exit_t = max(entries.values()) + 0.01
        for r in range(n_ranks):
            evs.append(CollectiveEvent(rank=r, group_id="g", op="AR",
                                       entry=entries[r], exit=exit_t))
        det.observe_instance(evs)
    alerts = det.check()
    assert alerts and alerts[0].rank == 1


@given(st.integers(2, 5), st.integers(3, 17), st.integers(2, 40),
       st.integers(0, 100))
def test_distributed_ce_matches_naive(b, s, vocab, seed):
    rng = np.random.default_rng(seed)
    padded = ((vocab + 7) // 8) * 8
    logits = np.zeros((b, s, padded), np.float32)
    logits[..., :vocab] = rng.normal(size=(b, s, vocab))
    labels = rng.integers(0, vocab, size=(b, s))
    ours = np.asarray(cross_entropy(jnp.asarray(logits),
                                    jnp.asarray(labels), vocab))
    # naive reference over the unpadded vocab
    x = logits[..., :vocab]
    m = x.max(-1, keepdims=True)
    lse = np.log(np.exp(x - m).sum(-1)) + m[..., 0]
    ref = lse - np.take_along_axis(x, labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(ours, ref, rtol=1e-4, atol=1e-4)


@given(st.sampled_from(["f32", "bf16", "s32", "pred", "u8"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_hlo_shape_bytes(dtype, dims):
    sizes = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "u8": 1}
    t = f"{dtype}[{','.join(map(str, dims))}]"
    n = 1
    for d in dims:
        n *= d
    assert shape_bytes(t) == n * sizes[dtype]
