"""Pod transport: digest wire codec, at-most-once RPC client, worker
verbs across a real process boundary, and the supervisor's
detect→respawn loop (fake clock + fake workers — no sleeps)."""
import struct
import threading

import numpy as np
import pytest

from repro.core.pod import PodDigest, merge_digests
from repro.core.straggler import GroupBlame, StragglerAlert
from repro.core.trace import WireFormatError
from repro.core.transport import (DIGEST_MAGIC, DIGEST_VERSION,
                                  DigestFormatError, PodClient,
                                  PodCrashedError, PodRemoteError,
                                  PodTimeoutError, decode_digest,
                                  encode_digest, pod_worker_main,
                                  spawn_pod_worker)
from repro.ft.heartbeat import HeartbeatMonitor
from repro.ft.supervisor import PodSupervisor


# -- codec ---------------------------------------------------------------------


def _full_digest() -> PodDigest:
    alerts = [
        StragglerAlert(group_id="grüppe/γ-0", rank=3, lateness=0.021,
                       mean=0.004, std=0.0013, zscore=5.4, window=48),
        StragglerAlert(group_id="g1", rank=-1, lateness=0.0, mean=0.0,
                       std=0.0, zscore=0.0, window=0),
    ]
    blame = GroupBlame(
        group_id="grüppe/γ-0", ranks=(0, 3, 7), culprit_rank=3,
        culprit_lateness=0.021, lateness={0: -0.01, 3: 0.021, 7: -0.011},
        wait={0: 0.02, 7: 0.018}, peer_wait=0.019, last_start=123.456789,
        instances=17)
    return PodDigest(
        pod=5, alerts=alerts, summaries={"grüppe/γ-0": blame},
        groups=2, ranks=6,
        flame_sids=np.array([2, 9, 11], dtype=np.int64),
        flame_weights=np.array([1.5, 0.25, 7.0]),
        group_ranks={"grüppe/γ-0": (0, 3, 7), "g1": (1, 2)},
        seq=42)


def _assert_digest_equal(a: PodDigest, b: PodDigest) -> None:
    assert (a.pod, a.seq, a.groups, a.ranks) == \
        (b.pod, b.seq, b.groups, b.ranks)
    assert a.alerts == b.alerts
    assert a.summaries == b.summaries
    assert a.group_ranks == b.group_ranks
    np.testing.assert_array_equal(a.flame_sids, b.flame_sids)
    np.testing.assert_array_equal(a.flame_weights, b.flame_weights)


def test_digest_round_trip_lossless():
    d = _full_digest()
    rt = decode_digest(encode_digest(d))
    _assert_digest_equal(d, rt)
    # the wire form is lossless where the publish form is not
    assert rt.summaries["grüppe/γ-0"].last_start == 123.456789


def test_empty_digest_round_trip():
    d = merge_digests([])
    rt = decode_digest(encode_digest(d))
    _assert_digest_equal(d, rt)
    assert rt.pod == -1 and rt.alerts == [] and rt.summaries == {}


def test_decode_rejects_bad_magic():
    data = bytearray(encode_digest(_full_digest()))
    data[:4] = b"NOPE"
    with pytest.raises(DigestFormatError, match="magic"):
        decode_digest(bytes(data))


def test_decode_rejects_unsupported_version():
    data = bytearray(encode_digest(_full_digest()))
    data[4:6] = struct.pack("<H", DIGEST_VERSION + 7)
    with pytest.raises(DigestFormatError, match="version"):
        decode_digest(bytes(data))
    data[4:6] = struct.pack("<H", 0)
    with pytest.raises(DigestFormatError, match="version"):
        decode_digest(bytes(data))


def test_encode_rejects_unknown_version():
    with pytest.raises(DigestFormatError):
        encode_digest(_full_digest(), version=DIGEST_VERSION + 1)


def test_decode_rejects_truncation():
    data = encode_digest(_full_digest())
    for cut in (3, 7, len(data) // 2, len(data) - 1):
        with pytest.raises(WireFormatError):
            decode_digest(data[:cut])


# -- client: deadline, retry, at-most-once, crash ------------------------------


class ScriptedConn:
    """Fake connection endpoint; ``script(seq, kind, payload)`` returns
    the replies (if any) to enqueue for that request."""

    def __init__(self, script):
        self.script = script
        self.sent = []
        self.inbox = []
        self.closed = False

    def send(self, msg):
        if self.closed:
            raise BrokenPipeError("closed")
        self.sent.append(msg)
        self.inbox.extend(self.script(*msg) or [])

    def poll(self, timeout=None):
        return bool(self.inbox)

    def recv(self):
        return self.inbox.pop(0)

    def close(self):
        self.closed = True


def _client(conn, **kw):
    kw.setdefault("timeout", 1.0)
    kw.setdefault("clock", lambda: 0.0)
    kw.setdefault("sleep", lambda s: None)
    return PodClient(conn, **kw)


def test_client_ok_and_remote_error():
    conn = ScriptedConn(lambda seq, kind, p:
                        [(seq, "err", "ValueError: boom")]
                        if kind == "bad" else [(seq, "ok", p)])
    c = _client(conn)
    assert c.call("echo", 7) == ("ok", 7)
    with pytest.raises(PodRemoteError, match="boom"):
        c.call("bad")


def test_client_retry_resends_same_seq_and_drops_stale():
    seen = []

    def script(seq, kind, payload):
        seen.append(seq)
        if len(seen) == 1:
            return []                     # first attempt: reply lost
        # late stale answer from an older call arrives first
        return [(seq - 1, "ok", "stale"), (seq, "ok", "fresh")]

    c = _client(ScriptedConn(script), retries=2)
    assert c.call("work") == ("ok", "fresh")
    assert seen == [1, 1]                 # retried with the SAME seq
    assert c.retries_used == 1 and c.timeouts == 1


def test_client_timeout_after_final_retry():
    c = _client(ScriptedConn(lambda *a: []), retries=2)
    with pytest.raises(PodTimeoutError):
        c.call("work")
    assert c.timeouts == 3                # initial + 2 retries


def test_client_dead_pipe_is_crash():
    conn = ScriptedConn(lambda *a: [])
    conn.close()
    with pytest.raises(PodCrashedError):
        _client(conn).call("ping")


def test_worker_duplicate_seq_not_reexecuted():
    """At-most-once across the real worker loop: a duplicate request
    seq is answered from the response cache, never re-executed."""
    import multiprocessing as mp
    parent, child = mp.Pipe()
    t = threading.Thread(target=pod_worker_main, args=(child, 0),
                         daemon=True)
    t.start()
    from repro.core.events import IterationProfile
    prof = IterationProfile(group_id="g", rank=0, iteration=1,
                            iter_time=0.1)
    req = (1, "ingest_profiles", ("job-0", [prof]))
    parent.send(req)
    assert parent.recv() == (1, "ok", 1)
    parent.send(req)                      # duplicate (retry after slow ack)
    assert parent.recv() == (1, "ok", 1)  # same cached answer
    parent.send((2, "stats", None))
    _, status, stats = parent.recv()
    assert status == "ok" and stats["ingested"] == 1.0
    parent.send((3, "nonsense", None))
    assert parent.recv()[1] == "err"
    parent.send((4, "stop", None))
    parent.recv()
    t.join(timeout=5.0)
    assert not t.is_alive()


# -- real process boundary -----------------------------------------------------


def test_worker_process_ping_collect_wedge_and_kill():
    proc, conn = spawn_pod_worker(7, nonce=3)
    client = PodClient(conn, timeout=10.0, retries=0)
    try:
        assert client.call("ping") == ("ok", ("pong", 7, 3))
        status, data = client.call("collect", 0.0)
        assert status == "ok"
        digest = decode_digest(data)
        assert digest.pod == 7 and digest.seq == 1
        # wedged worker: misses the deadline, then finishes sleeping
        # and answers the next call (stale answer is discarded)
        client.conn.send((999, "sleep", 0.4))   # not via call(): no wait
        with pytest.raises(PodTimeoutError):
            client.call("ping", timeout=0.05, retries=0)
        assert client.call("ping", timeout=10.0) == \
            ("ok", ("pong", 7, 3))
        proc.kill()
        proc.join(timeout=5.0)
        with pytest.raises((PodCrashedError, PodTimeoutError)):
            client.call("ping", timeout=0.5, retries=0)
    finally:
        client.close()
        if proc.is_alive():
            proc.kill()
        proc.join(timeout=5.0)


def test_client_backoff_capped_and_deterministic_jitter():
    """Retry backoff: linear growth capped at ``backoff_cap``, spread
    by jitter in [0.5, 1.0)x — and fully reproducible under a fake
    clock, while a different clock phase desynchronizes the herd."""
    def delays(clock_now):
        sleeps = []
        c = PodClient(ScriptedConn(lambda *a: []), timeout=1.0,
                      retries=3, backoff=0.05, backoff_cap=0.08,
                      clock=lambda: clock_now, sleep=sleeps.append)
        with pytest.raises(PodTimeoutError):
            c.call("work")
        return sleeps

    first = delays(0.123)
    assert len(first) == 3                # one sleep per retry
    for attempt, s in enumerate(first, 1):
        base = min(0.05 * attempt, 0.08)  # linear, then capped
        assert base * 0.5 <= s < base
    assert first[-1] < 0.08               # cap really binds on attempt 3
    assert delays(0.123) == first         # fake clock → exact replay
    assert delays(0.456) != first         # different phase → no herd


# -- real process boundary with shared-memory rings ----------------------------


def test_worker_process_ring_upload_and_ring_digest():
    """The zero-copy path end-to-end over a real fork: session frames
    encoded straight into the up ring and announced over the pipe,
    digests answered as down-ring records — and a bogus announcement is
    an error reply, never a hang."""
    from repro.core import simcluster as sc
    from repro.core.trace import ColumnarBatch, WireEncoder

    proc, conn, rings = spawn_pod_worker(3, nonce=1, ring_bytes=1 << 20)
    client = PodClient(conn, timeout=10.0, retries=0)
    try:
        cl = sc.cascade_fleet([[0, 1, 2, 3]], links=(), seed=5,
                              columnar=True, samples_per_iter=60)
        enc = WireEncoder(cl.tables)
        for _ in range(3):
            profiles = cl.step()
            batch = ColumnarBatch("job-0", profiles, "node-0", cl.tables)
            mv = rings.up.reserve_max()
            n = enc.encode_into(batch, mv)
            seq = rings.up.commit(n)
            assert client.call("ingest_ring", (seq, n)) == \
                ("ok", len(profiles))
            enc.commit()
        status, data = client.call("collect", 0.0)
        assert status == "ok"
        assert isinstance(data, tuple) and data[0] == "ring"
        _tag, rseq, nbytes = data
        seq, view = rings.down.pop()
        assert seq == rseq and len(view) == nbytes
        digest = decode_digest(view, detach=True)
        rings.down.release()
        assert digest.pod == 3 and digest.ranks == 4
        # bench sink verbs move bytes without decoding them
        payload = b"z" * 100000
        assert client.call("sink", payload) == ("ok", 100000)
        seq = rings.up.push(payload)
        assert client.call("sink_ring", (seq, len(payload))) == \
            ("ok", 100000)
        # a record the facade never committed cannot be served
        with pytest.raises(PodRemoteError, match="not committed"):
            client.call("ingest_ring", (99, 10))
    finally:
        client.close()
        if proc.is_alive():
            proc.kill()
        proc.join(timeout=5.0)


# -- supervisor: detect -> respawn, deterministically --------------------------


class FakeProc:
    def __init__(self):
        self.alive = True

    def is_alive(self):
        return self.alive

    def terminate(self):
        self.alive = False

    def kill(self):
        self.alive = False

    def join(self, timeout=None):
        pass


def _fake_supervisor(n=3, **kw):
    spawned = []

    def spawn(index, service_kwargs, nonce):
        proc = FakeProc()
        conn = ScriptedConn(
            lambda seq, kind, p, _i=index, _n=nonce:
            [(seq, "ok", ("pong", _i, _n))])
        spawned.append((index, nonce, proc))
        return proc, conn

    t = {"now": 0.0}
    kw.setdefault("heartbeat_interval_s", 1.0)
    kw.setdefault("miss_threshold", 3)
    sup = PodSupervisor(n, clock=lambda: t["now"], spawn=spawn, **kw)
    return sup, spawned, t


def test_supervisor_respawns_dead_worker_with_bumped_generation():
    sup, spawned, _ = _fake_supervisor()
    assert [s[:2] for s in spawned] == [(0, 0), (1, 0), (2, 0)]
    sup.workers[1].process.alive = False
    assert sup.live() == [0, 2]
    assert sup.supervise() == [1]
    assert sup.respawns == 1 and sup.generation(1) == 1
    assert spawned[-1][:2] == (1, 1)
    assert sup.live() == [0, 1, 2]
    assert sup.supervise() == []          # stable afterwards


def test_supervisor_respawns_wedged_worker_on_heartbeat_silence():
    sup, spawned, t = _fake_supervisor()
    t["now"] = 2.0
    sup.beat(0)
    sup.beat(2)                           # worker 1 stays silent
    t["now"] = 3.5                        # past interval * miss_threshold
    assert sup.supervise() == [1]
    assert sup.generation(1) == 1
    # respawn re-registered it: no repeat respawn without new silence
    assert sup.supervise() == []


def test_supervisor_ping_beats_and_shutdown_stops_all():
    sup, spawned, t = _fake_supervisor()
    t["now"] = 3.4
    assert sup.ping(0)                    # answers → beaten → survives
    assert sup.supervise() == [1, 2]
    sup.shutdown()
    assert sup.workers == {}
    assert all(not p.alive for _, _, p in spawned)


# -- heartbeat edge cases (the supervisor's failure detector) ------------------


def test_heartbeat_lag_clamped_and_register_clears_failure():
    t = {"now": 10.0}
    hb = HeartbeatMonitor(interval_s=1.0, miss_threshold=2,
                          clock=lambda: t["now"])
    hb.register("w")
    t["now"] = 9.0                        # clock regression
    assert hb.lag("w") == 0.0
    t["now"] = 13.0
    assert [f.node for f in hb.check()] == ["w"]
    assert hb.check() == []               # newly-failed only, no repeats
    assert hb.failed() == ["w"]
    hb.register("w")                      # respawn re-registers
    assert hb.failed() == [] and hb.alive() == ["w"]


def test_heartbeat_rejects_bad_config():
    with pytest.raises(ValueError):
        HeartbeatMonitor(interval_s=0.0)
    with pytest.raises(ValueError):
        HeartbeatMonitor(miss_threshold=0)
